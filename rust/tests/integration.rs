//! Cross-module integration tests: the full pipeline from the AOT
//! artifact through search, DSE, simulation and reporting — plus the
//! tier-1 sharded-search suite: a stub `CandidateEvaluator` drives a
//! `ShardedEngine` across several devices and every device's journal is
//! asserted bit-identical to a standalone single-device run.
//!
//! Tests that need the PJRT artifact skip (with a note) when
//! `artifacts/` has not been built — `make artifacts` first.

use hass::arch::networks;
use hass::baselines;
use hass::coordinator::{
    search, search_sharded, search_sharded_with_cache, CandidateEvaluator, DesignCache,
    Engine, EngineConfig, EvalCompletion, EvalError, EvalPoint, EvalRequest,
    MeasuredEvaluator, SearchConfig, SearchMode, SimulatedEvaluator, SurrogateEvaluator,
    INFEASIBLE_OBJECTIVE,
};
use hass::dse::{explore, explore_scan, network_throughput, DseConfig};
use hass::engine::quantize_points;
use hass::hardware::device::DeviceBudget;
use hass::hardware::resources::ResourceModel;
use hass::pruning::PruningPlan;
use hass::runtime::{available, default_dir, ModelRuntime};
use hass::simulator::{simulate, stages_from_design, SparsityDynamics};
use hass::sparsity::{synthesize, NetworkSparsity};

fn have_artifacts() -> bool {
    if available(&default_dir()) {
        true
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        false
    }
}

#[test]
fn measured_search_improves_objective_and_keeps_accuracy() {
    if !have_artifacts() {
        return;
    }
    let rt = ModelRuntime::load_default().unwrap();
    let ev = MeasuredEvaluator::new(rt, 1);
    let net = networks::calibnet();
    let cfg = SearchConfig {
        iterations: 14,
        mode: SearchMode::HardwareAware,
        seed: 1,
        ..Default::default()
    };
    let r = search(&ev, &net, &ResourceModel::default(), &DeviceBudget::u250(), &cfg);
    assert_eq!(r.records.len(), 14);
    let best = r.best_record();
    // the dense plan is always reachable, so the best objective must not
    // sacrifice more than a few accuracy points at λ = [0.1, 0.15, 0.1]
    assert!(
        best.accuracy > 55.0,
        "search settled on a broken operating point: {:.1}%",
        best.accuracy
    );
    // and must have found *some* sparsity (natural activation zeros alone
    // give a few percent)
    assert!(best.avg_sparsity > 0.05, "no sparsity found: {}", best.avg_sparsity);
}

#[test]
fn measured_points_feed_dse_and_simulator() {
    if !have_artifacts() {
        return;
    }
    let rt = ModelRuntime::load_default().unwrap();
    let ev = MeasuredEvaluator::new(rt, 1);
    let net = networks::calibnet();
    let n = net.compute_layers().len();
    let plan = PruningPlan::from_unit_point(&vec![0.4; 2 * n], ev.sparsity_model());
    let point = ev.eval(&plan);
    assert_eq!(point.points.len(), n);
    let rm = ResourceModel::default();
    let dev = DeviceBudget::u250();
    let d = explore(&net, &point.points, &rm, &dev, &DseConfig::default());
    assert!(dev.fits(&d.resources));
    let cfgs = stages_from_design(&net, &d.designs, &point.points, rm.fifo_depth);
    let rep = simulate(&net, &cfgs, 3, SparsityDynamics::Deterministic);
    assert!(!rep.deadlocked);
    let ratio = rep.throughput / d.throughput;
    assert!((0.9..1.1).contains(&ratio), "sim/model ratio {ratio}");
}

#[test]
fn runtime_accuracy_reacts_to_real_thresholds() {
    if !have_artifacts() {
        return;
    }
    let rt = ModelRuntime::load_default().unwrap();
    let l = rt.n_layers();
    // thresholds at the 60% weight quantile of each layer, from meta
    let sp = rt.meta.measured_sparsity();
    let tau_w: Vec<f64> = sp.layers.iter().map(|p| p.weight_curve.tau_for(0.6)).collect();
    let tau_a = vec![0.0; l];
    let out = rt.evaluate(&tau_w, &tau_a, 2).unwrap();
    // measured weight sparsity must land near the 60% target per layer
    for (i, &s) in out.s_w.iter().enumerate() {
        assert!((s - 0.6).abs() < 0.08, "layer {i}: S_w {s} (target 0.6)");
    }
    // a trained CalibNet tolerates 60% one-shot pruning reasonably well
    assert!(out.accuracy > 0.5, "accuracy collapsed: {}", out.accuracy);
}

#[test]
fn surrogate_and_measured_paths_share_the_search_machinery() {
    // same geometry, two evaluators — both must run through `search`
    let net = networks::calibnet();
    let cfg = SearchConfig {
        iterations: 6,
        mode: SearchMode::HardwareAware,
        seed: 2,
        ..Default::default()
    };
    let rm = ResourceModel::default();
    let dev = DeviceBudget::u250();
    let sur = SurrogateEvaluator {
        net: net.clone(),
        sparsity: synthesize(&net, 3),
        base_acc: 90.0,
    };
    let r1 = search(&sur, &net, &rm, &dev, &cfg);
    assert_eq!(r1.records.len(), 6);
    if have_artifacts() {
        let rt = ModelRuntime::load_default().unwrap();
        let mev = MeasuredEvaluator::new(rt, 1);
        let r2 = search(&mev, &net, &rm, &dev, &cfg);
        assert_eq!(r2.records.len(), 6);
    }
}

#[test]
fn baselines_and_hass_rank_as_the_paper_claims() {
    // capped device: efficiency differences must show
    let net = networks::calibnet();
    let sp = synthesize(&net, 1);
    let rm = ResourceModel::default();
    let dev = DeviceBudget { dsp: 768, ..DeviceBudget::u250() };
    let dse = DseConfig::default();
    let dense = baselines::dense_dataflow(&net, 90.0, &rm, &dev, &dse);
    let pass = baselines::pass_like(&net, &sp, 90.0, &rm, &dev, &dse);
    let ev = SurrogateEvaluator { net: net.clone(), sparsity: sp, base_acc: 90.0 };
    let cfg = SearchConfig {
        iterations: 24,
        mode: SearchMode::HardwareAware,
        seed: 4,
        ..Default::default()
    };
    let hass_best = search(&ev, &net, &rm, &dev, &cfg);
    let b = hass_best.best_record();
    assert!(
        pass.efficiency > dense.efficiency,
        "activation sparsity must beat dense: {} vs {}",
        pass.efficiency,
        dense.efficiency
    );
    assert!(
        b.efficiency > pass.efficiency,
        "HASS (both axes) must beat PASS (one axis): {} vs {}",
        b.efficiency,
        pass.efficiency
    );
}

#[test]
fn partitioned_resnet50_matches_throughput_model() {
    use hass::dse::partition::{evaluate_bounds, DEFAULT_RECONFIG_SECS};
    let net = networks::resnet50();
    let n = net.compute_layers().len();
    let points = vec![hass::sparsity::SparsityPoint { s_w: 0.5, s_a: 0.4 }; n];
    let rm = ResourceModel::default();
    let dev = DeviceBudget::u250();
    let cfg = DseConfig::default();
    // a hand-picked 2-way split must be feasible on the U250
    let p = evaluate_bounds(
        &net, &points, &rm, &dev, &cfg, &[0, n / 2, n], 4096, DEFAULT_RECONFIG_SECS,
    )
    .expect("2-way split fits");
    assert_eq!(p.n_partitions(), 2);
    for d in &p.designs {
        assert!(dev.fits(&d.resources));
    }
    // end-to-end rate must respect the per-partition bound
    let slowest = p
        .designs
        .iter()
        .map(|d| d.images_per_sec(&dev))
        .fold(f64::INFINITY, f64::min);
    assert!(p.images_per_sec <= slowest * 1.0001);
}

#[test]
fn end_to_end_deterministic_reproducibility() {
    // the whole surrogate pipeline, twice, bit-identical
    let run = || {
        let net = networks::resnet18();
        let sp = synthesize(&net, 9);
        let ev = SurrogateEvaluator { net: net.clone(), sparsity: sp, base_acc: 69.75 };
        let cfg = SearchConfig {
            iterations: 10,
            mode: SearchMode::HardwareAware,
            seed: 5,
            ..Default::default()
        };
        let r = search(&ev, &net, &ResourceModel::default(), &DeviceBudget::u250(), &cfg);
        r.records.iter().map(|x| x.objective.to_bits()).collect::<Vec<_>>()
    };
    assert_eq!(run(), run());
}

// ===== tier-1 sharded-search suite =====================================

/// Deterministic stub evaluator: decodes plans through a synthesized
/// sparsity model, but scores them with a closed-form quadratic accuracy
/// response — no surrogate machinery, no measurement, pure and cheap, so
/// these tests pin the *engine's* behavior and nothing else.
struct StubEvaluator {
    sparsity: NetworkSparsity,
}

impl StubEvaluator {
    fn calibnet(seed: u64) -> Self {
        StubEvaluator { sparsity: synthesize(&networks::calibnet(), seed) }
    }
}

impl CandidateEvaluator for StubEvaluator {
    fn sparsity_model(&self) -> &NetworkSparsity {
        &self.sparsity
    }

    fn eval(&self, plan: &PruningPlan) -> EvalPoint {
        let points = plan.points(&self.sparsity);
        let s = points.iter().map(|p| (p.s_w + p.s_a) * 0.5).sum::<f64>()
            / points.len() as f64;
        EvalPoint { accuracy: 92.0 - 30.0 * s * s, points, sim: Vec::new() }
    }

    fn base_accuracy(&self) -> f64 {
        92.0
    }
}

fn sharded_cfg(iters: usize, seed: u64, threads: usize) -> SearchConfig {
    SearchConfig {
        iterations: iters,
        seed,
        dse: DseConfig { max_iters: 1_500, ..Default::default() },
        engine: EngineConfig { batch: 4, threads, cache: true, quant_bits: 12, async_eval: false },
        ..Default::default()
    }
}

/// Deliberately slow, out-of-order-completing evaluator for the async
/// purity contract: `eval_async` measures the whole batch, then delivers
/// the completions in **reverse** submission order with a wall-clock
/// delay before each send.  A pipeline that depends on completion order
/// in any way journals differently from the sync engine; the tests below
/// assert it cannot.
struct SlowOooEvaluator {
    inner: StubEvaluator,
    delay: std::time::Duration,
}

impl SlowOooEvaluator {
    fn calibnet(seed: u64) -> Self {
        SlowOooEvaluator {
            inner: StubEvaluator::calibnet(seed),
            delay: std::time::Duration::from_millis(2),
        }
    }
}

impl CandidateEvaluator for SlowOooEvaluator {
    fn sparsity_model(&self) -> &NetworkSparsity {
        self.inner.sparsity_model()
    }

    fn eval(&self, plan: &PruningPlan) -> EvalPoint {
        self.inner.eval(plan)
    }

    fn base_accuracy(&self) -> f64 {
        self.inner.base_accuracy()
    }

    fn eval_async(
        &self,
        requests: Vec<EvalRequest>,
        completions: std::sync::mpsc::Sender<EvalCompletion>,
    ) {
        let mut done: Vec<EvalCompletion> = requests
            .into_iter()
            .map(|r| EvalCompletion { slot: r.slot, result: Ok(self.eval(&r.plan)) })
            .collect();
        done.reverse();
        for c in done {
            std::thread::sleep(self.delay);
            if completions.send(c).is_err() {
                return;
            }
        }
    }
}

fn objective_bits_of(r: &hass::coordinator::SearchResult) -> Vec<u64> {
    r.records.iter().map(|x| x.objective.to_bits()).collect()
}

/// The async-pipeline purity contract: a slow evaluator that completes
/// strictly out of submission order must journal — on every device —
/// bit-identically to the sync two-phase engine driving the plain stub,
/// across thread counts.
#[test]
fn async_out_of_order_evaluator_matches_sync_stub_bit_for_bit() {
    let sync_ev = StubEvaluator::calibnet(60);
    let ooo_ev = SlowOooEvaluator::calibnet(60);
    let net = networks::calibnet();
    let rm = ResourceModel::default();
    let devices = [DeviceBudget::u250(), DeviceBudget::v7_690t()];
    let sync_cfg = sharded_cfg(12, 19, 0);
    let sync = search_sharded(&sync_ev, &net, &rm, &devices, &sync_cfg);
    for threads in [1usize, 0] {
        let mut cfg = sharded_cfg(12, 19, threads);
        cfg.engine.async_eval = true;
        let async_r = search_sharded(&ooo_ev, &net, &rm, &devices, &cfg);
        assert_eq!(async_r.stats.async_generations, async_r.stats.generations);
        for (a, b) in sync.per_device.iter().zip(&async_r.per_device) {
            assert_eq!(a.device, b.device);
            assert_eq!(
                objective_bits_of(&a.result),
                objective_bits_of(&b.result),
                "{} (threads={threads}): async out-of-order journal diverged",
                a.device
            );
            assert_eq!(a.result.best, b.result.best);
            assert_eq!(
                a.result.best_record().plan,
                b.result.best_record().plan
            );
        }
        // reverse delivery means every completion after the first arrives
        // below the max slot seen — the engine must both notice it...
        assert!(
            async_r.stats.ooo_completions > 0,
            "reverse-order evaluator must register out-of-order completions"
        );
        // ...and price earlier completions while the evaluator is still
        // delivering the rest of the batch
        assert!(
            async_r.stats.overlap_pricings > 0,
            "pricing must overlap the still-running measurement batch"
        );
    }
}

/// The async pipeline under the production surrogate evaluator (default
/// serial `eval_async`): bit-identical to the sync engine, standalone
/// and sharded.
#[test]
fn async_surrogate_matches_sync_bit_for_bit() {
    let net = networks::calibnet();
    let ev = SurrogateEvaluator {
        net: net.clone(),
        sparsity: synthesize(&net, 12),
        base_acc: 85.0,
    };
    let rm = ResourceModel::default();
    let devices = [DeviceBudget::u250(), DeviceBudget::v7_690t()];
    let sync = search_sharded(&ev, &net, &rm, &devices, &sharded_cfg(10, 23, 0));
    let mut acfg = sharded_cfg(10, 23, 0);
    acfg.engine.async_eval = true;
    let async_r = search_sharded(&ev, &net, &rm, &devices, &acfg);
    for (a, b) in sync.per_device.iter().zip(&async_r.per_device) {
        assert_eq!(a.device, b.device);
        assert_eq!(
            objective_bits_of(&a.result),
            objective_bits_of(&b.result),
            "{}: async surrogate journal diverged from sync",
            a.device
        );
    }
    // the async pipeline still dedups cross-shard startup proposals
    assert_eq!(sync.stats.dedup_evals, async_r.stats.dedup_evals);
    // and the sync run reports no async activity
    assert_eq!(sync.stats.async_generations, 0);
    assert_eq!(sync.stats.overlap_pricings, 0);
    assert_eq!(sync.stats.ooo_completions, 0);
}

/// The tentpole acceptance test: a `ShardedEngine` over three devices
/// produces, for every device, the bit-identical journal of a standalone
/// `Engine::search` on that device with the same seed.
#[test]
fn sharded_journals_match_standalone_bit_for_bit() {
    let ev = StubEvaluator::calibnet(40);
    let net = networks::calibnet();
    let rm = ResourceModel::default();
    let devices =
        [DeviceBudget::u250(), DeviceBudget::v7_690t(), DeviceBudget::stratix10()];
    let cfg = sharded_cfg(14, 6, 0);
    let sharded = search_sharded(&ev, &net, &rm, &devices, &cfg);
    assert_eq!(sharded.stats.devices, 3);
    assert_eq!(sharded.stats.evaluations, 3 * 14);
    // a healthy run consumes none of the fault-tolerance machinery
    assert_eq!(sharded.stats.retried_evals, 0);
    assert_eq!(sharded.stats.reclaimed_stalls, 0);
    for dev in &devices {
        let standalone = Engine::new(&ev, &net, &rm, dev).search(&cfg);
        let shard = sharded.by_device(&dev.name).expect("device in sharded result");
        assert_eq!(standalone.records.len(), shard.records.len());
        for (a, b) in standalone.records.iter().zip(&shard.records) {
            assert_eq!(a.iter, b.iter);
            assert_eq!(
                a.objective.to_bits(),
                b.objective.to_bits(),
                "{} iter {}: sharded journal diverged from standalone",
                dev.name,
                a.iter
            );
            assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
            assert_eq!(a.images_per_sec.to_bits(), b.images_per_sec.to_bits());
            assert_eq!(a.plan, b.plan);
        }
        assert_eq!(standalone.best, shard.best);
        assert_eq!(
            standalone.efficiency_trajectory(),
            shard.efficiency_trajectory()
        );
    }
}

/// Thread count is an execution knob, never an algorithmic one — a
/// sharded run on one worker matches a sharded run on the full pool.
#[test]
fn sharded_search_is_thread_count_invariant() {
    let ev = StubEvaluator::calibnet(41);
    let net = networks::calibnet();
    let rm = ResourceModel::default();
    let devices = [DeviceBudget::u250(), DeviceBudget::v7_690t()];
    let serial = search_sharded(&ev, &net, &rm, &devices, &sharded_cfg(10, 9, 1));
    let pooled = search_sharded(&ev, &net, &rm, &devices, &sharded_cfg(10, 9, 0));
    for (a, b) in serial.per_device.iter().zip(&pooled.per_device) {
        assert_eq!(a.device, b.device);
        for (x, y) in a.result.records.iter().zip(&b.result.records) {
            assert_eq!(x.objective.to_bits(), y.objective.to_bits());
        }
    }
}

/// Every journal record of every device is weakly dominated by some point
/// of the cross-device frontier (the frontier is a true upper staircase).
#[test]
fn cross_device_pareto_front_dominates_all_records() {
    let ev = StubEvaluator::calibnet(42);
    let net = networks::calibnet();
    let rm = ResourceModel::default();
    let devices = [DeviceBudget::u250(), DeviceBudget::v7_690t()];
    let r = search_sharded(&ev, &net, &rm, &devices, &sharded_cfg(12, 3, 0));
    assert!(!r.pareto.is_empty());
    for d in &r.per_device {
        for rec in &d.result.records {
            assert!(
                r.pareto.iter().any(|p| {
                    p.accuracy >= rec.accuracy && p.efficiency >= rec.efficiency
                }),
                "{}#{} not covered by the frontier",
                d.device,
                rec.iter
            );
        }
    }
    // frontier points carry their provenance
    for p in &r.pareto {
        assert!(devices.iter().any(|d| d.name == p.device));
    }
}

/// End-to-end composition: the best sharded design on each device still
/// fits its budget and survives the cycle-level simulator.
#[test]
fn sharded_best_designs_fit_and_simulate() {
    let ev = StubEvaluator::calibnet(43);
    let net = networks::calibnet();
    let rm = ResourceModel::default();
    let devices = [DeviceBudget::u250(), DeviceBudget::v7_690t()];
    let cfg = sharded_cfg(8, 5, 0);
    let r = search_sharded(&ev, &net, &rm, &devices, &cfg);
    for (dev, d) in devices.iter().zip(&r.per_device) {
        let best = d.result.best_record();
        // re-derive the journaled design exactly: same DSE config, same
        // pricing quantization the search used
        let point = ev.eval(&best.plan);
        let pts = quantize_points(&point.points, cfg.engine.quant_bits);
        let design = explore(&net, &pts, &rm, dev, &cfg.dse);
        assert!(dev.fits(&design.resources), "{}: best design overflows", dev.name);
        assert_eq!(
            design.resources.dsp, best.dsp,
            "{}: re-derived design disagrees with the journal",
            dev.name
        );
        let cfgs = stages_from_design(&net, &design.designs, &pts, rm.fifo_depth);
        let rep = simulate(&net, &cfgs, 2, SparsityDynamics::Deterministic);
        assert!(!rep.deadlocked, "{}: deadlock", dev.name);
    }
}

/// Cross-module differential for the frontier pricing kernel: on full
/// paper geometries, frontier-based `explore` must reproduce the seed
/// scan bit for bit (designs, throughput, resources).
#[test]
fn frontier_explore_matches_scan_on_paper_geometries() {
    let rm = ResourceModel::default();
    let dev = DeviceBudget::u250();
    for (name, s) in [("resnet18", 0.55), ("mobilenet_v2", 0.2)] {
        let net = networks::by_name(name).unwrap();
        let n = net.compute_layers().len();
        let points = vec![hass::sparsity::SparsityPoint { s_w: s, s_a: 0.8 * s }; n];
        let fast = explore(&net, &points, &rm, &dev, &DseConfig::default());
        let scan = explore_scan(&net, &points, &rm, &dev, &DseConfig::default());
        assert_eq!(fast.designs, scan.designs, "{name}/s={s}: designs diverged");
        assert_eq!(
            fast.throughput.to_bits(),
            scan.throughput.to_bits(),
            "{name}/s={s}: throughput diverged"
        );
        assert_eq!(fast.resources, scan.resources, "{name}/s={s}");
    }
}

/// Cross-shard dedup + frontier reuse through the public sharded API:
/// within the TPE startup budget every shard proposes identical
/// candidates, so all but one shard's measurements are deduped — while
/// journals stay bit-identical to standalone runs (asserted above).
#[test]
fn sharded_search_dedups_startup_and_reuses_frontiers() {
    let ev = StubEvaluator::calibnet(44);
    let net = networks::calibnet();
    let rm = ResourceModel::default();
    let devices = [DeviceBudget::u250(), DeviceBudget::v7_690t()];
    let iters = 8; // < TPE n_startup (10): all proposals are model-free
    let r = search_sharded(&ev, &net, &rm, &devices, &sharded_cfg(iters, 11, 0));
    assert_eq!(
        r.stats.dedup_evals,
        iters as u64,
        "second shard must dedup every startup measurement"
    );
    assert_eq!(r.per_device[0].result.stats.dedup_evals, 0);
    assert_eq!(r.per_device[1].result.stats.dedup_evals, iters as u64);
    // the pricing device populated (and shared) the frontier store
    let u250 = &r.per_device[0].result.stats;
    assert!(u250.frontier_misses > 0, "cold search must build frontiers");
    assert!(r.stats.frontier_entries > 0);
    // pricing itself is never deduped: each shard prices every candidate
    for d in &r.per_device {
        let s = &d.result.stats;
        assert_eq!(s.cache_hits + s.cache_misses, iters as u64, "{}", d.device);
    }
}

/// The persistence tentpole invariant: a warm-**from-disk** repeat of a
/// sharded search journals bit-identically to the cold run, misses the
/// design cache zero times, and never touches the frontier store (the
/// dense reference and every candidate pricing come straight off disk).
#[test]
fn warm_from_disk_search_is_bit_identical_with_zero_misses() {
    let ev = StubEvaluator::calibnet(50);
    let net = networks::calibnet();
    let rm = ResourceModel::default();
    let devices = [DeviceBudget::u250(), DeviceBudget::v7_690t()];
    let cfg = sharded_cfg(10, 13, 0);
    let cache = DesignCache::new();
    let cold = search_sharded_with_cache(&ev, &net, &rm, &devices, &cfg, &cache);
    assert!(cold.stats.cache_misses > 0, "cold run must miss");
    let path = std::env::temp_dir().join("hass_warm_from_disk_test.json");
    // snapshot saves merge with whatever is already on disk; a stale file
    // from an interrupted earlier run must not leak into this one
    std::fs::remove_file(&path).ok();
    let saved = cache.save(&path).unwrap();
    assert!(saved.designs > 0, "snapshot must carry the design memo");
    assert!(saved.frontiers > 0, "snapshot must carry the frontier store");
    let (warm_cache, loaded) = DesignCache::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.designs, saved.designs);
    assert_eq!(loaded.frontiers, saved.frontiers);
    assert_eq!(loaded.skipped, 0);
    let warm = search_sharded_with_cache(&ev, &net, &rm, &devices, &cfg, &warm_cache);
    assert_eq!(
        warm.stats.cache_misses, 0,
        "a warm-from-disk repeat must serve every pricing from the snapshot"
    );
    assert_eq!(warm.stats.cache_hits, 2 * 10);
    assert_eq!(warm.stats.frontier_hits + warm.stats.frontier_misses, 0);
    for (a, b) in cold.per_device.iter().zip(&warm.per_device) {
        assert_eq!(a.device, b.device);
        assert_eq!(a.result.records.len(), b.result.records.len());
        for (x, y) in a.result.records.iter().zip(&b.result.records) {
            assert_eq!(
                x.objective.to_bits(),
                y.objective.to_bits(),
                "{} iter {}: warm-from-disk journal diverged",
                a.device,
                x.iter
            );
            assert_eq!(x.accuracy.to_bits(), y.accuracy.to_bits());
            assert_eq!(x.images_per_sec.to_bits(), y.images_per_sec.to_bits());
            assert_eq!(x.dsp, y.dsp);
            assert_eq!(x.plan, y.plan);
        }
        assert_eq!(a.result.best, b.result.best);
    }
}

// ===== panic-free search paths ==========================================

/// Evaluator that *fails* as a pure function of the plan: any plan whose
/// summed weight sparsity exceeds `fail_above` returns `Err` from
/// `try_eval`.  Purity is the load-bearing property — an impure failure
/// predicate (a call counter, a clock) would make journals
/// nondeterministic, which the bit-identity assertions below would catch.
struct FlakyEvaluator {
    sparsity: NetworkSparsity,
    fail_above: f64,
}

impl FlakyEvaluator {
    fn calibnet(seed: u64, fail_above: f64) -> Self {
        FlakyEvaluator { sparsity: synthesize(&networks::calibnet(), seed), fail_above }
    }
}

impl CandidateEvaluator for FlakyEvaluator {
    fn sparsity_model(&self) -> &NetworkSparsity {
        &self.sparsity
    }

    fn eval(&self, plan: &PruningPlan) -> EvalPoint {
        self.try_eval(plan).expect("engine must call try_eval, not eval")
    }

    fn try_eval(&self, plan: &PruningPlan) -> Result<EvalPoint, EvalError> {
        let points = plan.points(&self.sparsity);
        let s: f64 = points.iter().map(|p| p.s_w).sum();
        if s > self.fail_above {
            return Err(format!("backend rejected plan (s = {s:.3})"));
        }
        Ok(EvalPoint { accuracy: 92.0 - 10.0 * s, points, sim: Vec::new() })
    }

    fn base_accuracy(&self) -> f64 {
        92.0
    }
}

/// A backend that fails **every** measurement must not kill the search:
/// all iterations complete, every record is scored with the finite
/// infeasible objective (TPE asserts finiteness — `NEG_INFINITY` would
/// abort it), and the journal is bit-identical between the sync and
/// async pipelines.
#[test]
fn all_failing_evaluations_complete_the_search_infeasibly() {
    let ev = FlakyEvaluator::calibnet(70, -1.0); // s >= 0 always: all plans fail
    let net = networks::calibnet();
    let rm = ResourceModel::default();
    let dev = DeviceBudget::u250();
    let cfg = sharded_cfg(10, 17, 0);
    let r = search(&ev, &net, &rm, &dev, &cfg);
    assert_eq!(r.records.len(), 10, "failures must not shorten the journal");
    for rec in &r.records {
        assert!(rec.objective.is_finite(), "infeasible objective must stay finite");
        assert_eq!(rec.objective, INFEASIBLE_OBJECTIVE, "iter {}", rec.iter);
        assert_eq!(rec.accuracy, 0.0);
        assert_eq!(rec.images_per_sec, 0.0);
        assert!(!rec.simulated);
    }
    let mut acfg = sharded_cfg(10, 17, 0);
    acfg.engine.async_eval = true;
    let r2 = search(&ev, &net, &rm, &dev, &acfg);
    assert_eq!(
        objective_bits_of(&r),
        objective_bits_of(&r2),
        "failing-evaluator journal diverged between sync and async pipelines"
    );
}

/// A never-failing `try_eval` journals identically across the sync and
/// async pipelines — the error plumbing costs nothing when unused — and
/// a cache that lived through an all-failing search serves a healthy
/// search afterwards (failures never poison or pollute the stores).
#[test]
fn failure_plumbing_is_free_and_never_poisons_the_cache() {
    let net = networks::calibnet();
    let rm = ResourceModel::default();
    let devices = [DeviceBudget::u250()];
    let cfg = sharded_cfg(8, 21, 0);
    let healthy = StubEvaluator::calibnet(70);
    let never_fails = FlakyEvaluator::calibnet(70, f64::INFINITY);
    let a = search_sharded(&never_fails, &net, &rm, &devices, &cfg);
    let mut acfg = sharded_cfg(8, 21, 0);
    acfg.engine.async_eval = true;
    let b = search_sharded(&never_fails, &net, &rm, &devices, &acfg);
    assert_eq!(
        objective_bits_of(&a.per_device[0].result),
        objective_bits_of(&b.per_device[0].result)
    );
    // ...and cache survival: an all-failing search over a shared cache,
    // then a healthy one on the same cache
    let cache = DesignCache::new();
    let all_fail = FlakyEvaluator::calibnet(70, -1.0);
    let failed = search_sharded_with_cache(&all_fail, &net, &rm, &devices, &cfg, &cache);
    assert!(failed
        .per_device[0]
        .result
        .records
        .iter()
        .all(|r| r.objective == INFEASIBLE_OBJECTIVE));
    let after = search_sharded_with_cache(&healthy, &net, &rm, &devices, &cfg, &cache);
    assert_eq!(after.per_device[0].result.records.len(), 8);
    assert!(
        after.per_device[0].result.best_record().objective > INFEASIBLE_OBJECTIVE,
        "healthy search on the shared cache must find a feasible best"
    );
}

/// `--iters 0` is a legal smoke run: empty journal, no best record, no
/// panic anywhere on the result surface.
#[test]
fn zero_iteration_search_has_no_best_and_no_panics() {
    let ev = StubEvaluator::calibnet(71);
    let net = networks::calibnet();
    let r = search(
        &ev,
        &net,
        &ResourceModel::default(),
        &DeviceBudget::u250(),
        &sharded_cfg(0, 1, 0),
    );
    assert!(r.records.is_empty());
    assert!(r.try_best_record().is_none(), "no iterations -> no best record");
    assert!(r.efficiency_trajectory().is_empty());
    let csv = r.to_table().to_csv();
    assert_eq!(csv.lines().count(), 1, "journal must be header-only: {csv:?}");
}

/// An unwritable journal path is an `Err` from `write_journal`, not a
/// panic (the CLI turns it into exit code 1).
#[test]
fn journal_write_failure_is_an_error_not_a_panic() {
    let ev = StubEvaluator::calibnet(72);
    let net = networks::calibnet();
    let r = search(
        &ev,
        &net,
        &ResourceModel::default(),
        &DeviceBudget::u250(),
        &sharded_cfg(2, 1, 0),
    );
    // the parent "directory" is an existing *file*, so create_dir_all fails
    let blocker = std::env::temp_dir().join("hass_journal_blocker_test");
    std::fs::write(&blocker, "occupied").unwrap();
    let path = blocker.join("journal.csv");
    let err = r.write_journal(path.to_str().unwrap());
    std::fs::remove_file(&blocker).ok();
    assert!(err.is_err(), "writing under a file must fail gracefully");
}

#[test]
fn dse_design_survives_simulator_stress() {
    // stochastic dynamics + tight FIFOs: no deadlock, bounded slowdown
    let net = networks::calibnet();
    let n = net.compute_layers().len();
    let points = vec![hass::sparsity::SparsityPoint { s_w: 0.6, s_a: 0.5 }; n];
    let rm = ResourceModel::default();
    let dev = DeviceBudget::u250();
    let d = explore(&net, &points, &rm, &dev, &DseConfig::default());
    let model = network_throughput(&net, &d.designs, &points);
    for seed in [1u64, 2, 3] {
        let mut cfgs = stages_from_design(&net, &d.designs, &points, 64);
        for c in cfgs.iter_mut() {
            c.fifo_capacity = (c.design.o_par as u64 * 4).max(16);
        }
        let rep = simulate(&net, &cfgs, 3, SparsityDynamics::Stochastic { seed });
        assert!(!rep.deadlocked, "seed {seed} deadlocked");
        assert!(
            rep.throughput > model * 0.3,
            "seed {seed}: stochastic collapse {} vs {model}",
            rep.throughput
        );
    }
}

// ===== fidelity-laddered search =========================================

/// Tentpole acceptance: a fidelity-laddered search (`SimulatedEvaluator`
/// wrapping the stub backend) journals bit-identically across worker
/// thread counts, actually simulator-scores some records, and leaves the
/// unpromoted majority on their analytic score.
#[test]
fn sim_evaluator_laddered_search_is_thread_invariant() {
    let net = networks::calibnet();
    let rm = ResourceModel::default();
    let dev = DeviceBudget::u250();
    let run = |threads: usize| {
        let ev = SimulatedEvaluator {
            inner: Box::new(StubEvaluator::calibnet(61)),
            target: net.clone(),
            rm: rm.clone(),
            devices: vec![dev.clone()],
            dse: DseConfig { max_iters: 1_500, ..Default::default() },
            top_k: 2,
            sim_images: 2,
        };
        let mut cfg = sharded_cfg(12, 31, threads);
        cfg.engine.async_eval = true; // the ladder ranks per generation
        search(&ev, &net, &rm, &dev, &cfg)
    };
    let a = run(1);
    let b = run(0);
    assert!(a.stats.sim_evals > 0, "ladder never reached the simulator");
    assert!(
        a.stats.sim_evals < a.records.len(),
        "ladder must be selective: {} of {} records simulated",
        a.stats.sim_evals,
        a.records.len()
    );
    assert_eq!(a.stats.sim_evals, b.stats.sim_evals);
    assert_eq!(a.stats.sim_promotions, b.stats.sim_promotions);
    assert_eq!(
        a.stats.sim_disagreement.to_bits(),
        b.stats.sim_disagreement.to_bits()
    );
    assert_eq!(
        objective_bits_of(&a),
        objective_bits_of(&b),
        "laddered journal diverged across thread counts"
    );
    assert_eq!(a.best, b.best);
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.simulated, y.simulated, "iter {}", x.iter);
        assert_eq!(x.images_per_sec.to_bits(), y.images_per_sec.to_bits());
        assert_eq!(
            x.analytic_images_per_sec.to_bits(),
            y.analytic_images_per_sec.to_bits()
        );
        if !x.simulated {
            assert_eq!(
                x.images_per_sec.to_bits(),
                x.analytic_images_per_sec.to_bits(),
                "iter {}: unpromoted record drifted off its analytic score",
                x.iter
            );
        }
    }
}

/// The sharded laddered search: one `SimulatedEvaluator` spanning two
/// device shards.  Promotion is the union of each device's analytic
/// top-k and every promoted candidate is simulated on *every* device, so
/// each shard scores sim-overrides off its own device's report.  The
/// invariant here is thread-count invariance (standalone equivalence does
/// not hold for the ladder — a lone device would promote a different set).
#[test]
fn sharded_laddered_search_is_thread_invariant_and_device_scoped() {
    let net = networks::calibnet();
    let rm = ResourceModel::default();
    let devices = [DeviceBudget::u250(), DeviceBudget::v7_690t()];
    let run = |threads: usize| {
        let ev = SimulatedEvaluator {
            inner: Box::new(StubEvaluator::calibnet(62)),
            target: net.clone(),
            rm: rm.clone(),
            devices: devices.to_vec(),
            dse: DseConfig { max_iters: 1_500, ..Default::default() },
            top_k: 1,
            sim_images: 2,
        };
        let mut cfg = sharded_cfg(8, 33, threads);
        cfg.engine.async_eval = true;
        search_sharded(&ev, &net, &rm, &devices, &cfg)
    };
    let a = run(1);
    let b = run(0);
    assert!(a.stats.sim_evals > 0, "sharded ladder never reached the simulator");
    assert_eq!(a.stats.sim_evals, b.stats.sim_evals);
    assert_eq!(a.stats.sim_promotions, b.stats.sim_promotions);
    for (x, y) in a.per_device.iter().zip(&b.per_device) {
        assert_eq!(x.device, y.device);
        assert!(
            x.result.stats.sim_evals > 0,
            "{}: shard never simulator-scored a record",
            x.device
        );
        assert_eq!(x.result.best, y.result.best);
        for (p, q) in x.result.records.iter().zip(&y.result.records) {
            assert_eq!(p.simulated, q.simulated, "{} iter {}", x.device, p.iter);
            assert_eq!(
                p.objective.to_bits(),
                q.objective.to_bits(),
                "{} iter {}: sharded laddered journal diverged",
                x.device,
                p.iter
            );
            assert_eq!(p.images_per_sec.to_bits(), q.images_per_sec.to_bits());
            assert_eq!(
                p.analytic_images_per_sec.to_bits(),
                q.analytic_images_per_sec.to_bits()
            );
        }
    }
}

// ===== cross-generation lookahead pipeline ==============================

/// Full per-record journal fingerprint (not just objectives): any drift
/// in accuracy, throughput, resources or the plan itself fails the
/// bit-identity assertions below.
fn journal_bits_of(r: &hass::coordinator::SearchResult) -> Vec<(u64, u64, u64, u64)> {
    r.records
        .iter()
        .map(|x| {
            (
                x.objective.to_bits(),
                x.accuracy.to_bits(),
                x.images_per_sec.to_bits(),
                x.dsp,
            )
        })
        .collect()
}

fn assert_sharded_equal(
    a: &hass::engine::ShardedSearchResult,
    b: &hass::engine::ShardedSearchResult,
    what: &str,
) {
    for (x, y) in a.per_device.iter().zip(&b.per_device) {
        assert_eq!(x.device, y.device);
        assert_eq!(
            journal_bits_of(&x.result),
            journal_bits_of(&y.result),
            "{}: {what} journal diverged",
            x.device
        );
        for (p, q) in x.result.records.iter().zip(&y.result.records) {
            assert_eq!(p.plan, q.plan, "{} iter {}: {what} plan diverged", x.device, p.iter);
        }
        assert_eq!(x.result.best, y.result.best);
    }
}

/// `--pipeline-depth 0` is the classic drained engine: byte-identical
/// journals to a run that never mentions the flag, and every pipeline
/// counter stays zero.
#[test]
fn pipeline_depth_zero_is_the_drained_engine() {
    let ev = StubEvaluator::calibnet(64);
    let net = networks::calibnet();
    let rm = ResourceModel::default();
    let devices = [DeviceBudget::u250(), DeviceBudget::v7_690t()];
    let base = search_sharded(&ev, &net, &rm, &devices, &sharded_cfg(12, 41, 0));
    let mut cfg = sharded_cfg(12, 41, 0);
    cfg.pipeline_depth = 0; // explicit, same as the default
    let zero = search_sharded(&ev, &net, &rm, &devices, &cfg);
    assert_sharded_equal(&base, &zero, "depth-0");
    assert_eq!(zero.stats.pipelined_generations, 0);
    assert_eq!(zero.stats.lookahead_proposals, 0);
    assert_eq!(zero.stats.barrier_wait_ns, 0, "depth 0 must not even time a barrier");
}

/// The net invariant for a fixed depth: thread counts, sync vs async
/// (with an adversarially slow, out-of-order evaluator) and cold vs warm
/// caches all journal bit-identically — only the depth itself is
/// algorithmic.
#[test]
fn pipeline_journals_are_execution_invariant_for_fixed_depth() {
    let net = networks::calibnet();
    let rm = ResourceModel::default();
    let devices = [DeviceBudget::u250(), DeviceBudget::v7_690t()];
    for depth in [1usize, 2] {
        let mut ref_cfg = sharded_cfg(12, 43, 0);
        ref_cfg.pipeline_depth = depth;
        let reference =
            search_sharded(&StubEvaluator::calibnet(65), &net, &rm, &devices, &ref_cfg);
        assert!(
            reference.stats.pipelined_generations > 0,
            "depth {depth}: generations never overlapped"
        );
        assert!(
            reference.stats.lookahead_proposals > 0,
            "depth {depth}: no proposal was drawn ahead of its observations"
        );
        // thread counts, sync path
        for threads in [1usize, 2] {
            let mut cfg = sharded_cfg(12, 43, threads);
            cfg.pipeline_depth = depth;
            let r = search_sharded(&StubEvaluator::calibnet(65), &net, &rm, &devices, &cfg);
            assert_sharded_equal(&reference, &r, "threaded pipelined");
            assert_eq!(r.stats.pipelined_generations, reference.stats.pipelined_generations);
            assert_eq!(r.stats.lookahead_proposals, reference.stats.lookahead_proposals);
        }
        // async completion queue, out-of-order slow evaluator
        for threads in [0usize, 1] {
            let mut cfg = sharded_cfg(12, 43, threads);
            cfg.pipeline_depth = depth;
            cfg.engine.async_eval = true;
            let r =
                search_sharded(&SlowOooEvaluator::calibnet(65), &net, &rm, &devices, &cfg);
            assert_sharded_equal(&reference, &r, "async pipelined");
            assert!(r.stats.ooo_completions > 0, "the evaluator completes in reverse");
        }
        // cold vs warm shared cache
        let cache = DesignCache::new();
        let cold = search_sharded_with_cache(
            &StubEvaluator::calibnet(65),
            &net,
            &rm,
            &devices,
            &ref_cfg,
            &cache,
        );
        let warm = search_sharded_with_cache(
            &StubEvaluator::calibnet(65),
            &net,
            &rm,
            &devices,
            &ref_cfg,
            &cache,
        );
        assert!(warm.stats.cache_hits > cold.stats.cache_hits, "second run must hit");
        assert_sharded_equal(&reference, &cold, "cold-cache pipelined");
        assert_sharded_equal(&reference, &warm, "warm-cache pipelined");
    }
}

/// Depth is algorithmic, not cosmetic: once the TPE model engages, a
/// depth-2 schedule proposes from older observations than the drained
/// schedule and the journals genuinely diverge.  (At depth 0 they could
/// not — that is the previous test.)
#[test]
fn pipeline_depth_changes_the_search_trajectory_once_the_model_engages() {
    let net = networks::calibnet();
    let rm = ResourceModel::default();
    let devices = [DeviceBudget::u250()];
    // 5 generations x batch 4: the drained run crosses TPE's startup
    // threshold (10 observations) at generation 3's proposal time, the
    // depth-2 run only at generation 4's — the schedules must differ
    let drained = search_sharded(
        &StubEvaluator::calibnet(66),
        &net,
        &rm,
        &devices,
        &sharded_cfg(20, 47, 0),
    );
    let mut cfg = sharded_cfg(20, 47, 0);
    cfg.pipeline_depth = 2;
    let piped =
        search_sharded(&StubEvaluator::calibnet(66), &net, &rm, &devices, &cfg);
    let a = journal_bits_of(&drained.per_device[0].result);
    let b = journal_bits_of(&piped.per_device[0].result);
    assert_eq!(a.len(), b.len());
    assert_ne!(a, b, "a positive lookahead depth must change the proposal schedule");
}
