//! Cross-module integration tests: the full pipeline from the AOT
//! artifact through search, DSE, simulation and reporting.
//!
//! Tests that need the PJRT artifact skip (with a note) when
//! `artifacts/` has not been built — `make artifacts` first.

use hass::arch::networks;
use hass::baselines;
use hass::coordinator::{
    search, Evaluate, MeasuredEvaluator, SearchConfig, SearchMode, SurrogateEvaluator,
};
use hass::dse::{explore, network_throughput, DseConfig};
use hass::hardware::device::DeviceBudget;
use hass::hardware::resources::ResourceModel;
use hass::pruning::PruningPlan;
use hass::runtime::{available, default_dir, ModelRuntime};
use hass::simulator::{simulate, stages_from_design, SparsityDynamics};
use hass::sparsity::synthesize;

fn have_artifacts() -> bool {
    if available(&default_dir()) {
        true
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        false
    }
}

#[test]
fn measured_search_improves_objective_and_keeps_accuracy() {
    if !have_artifacts() {
        return;
    }
    let rt = ModelRuntime::load_default().unwrap();
    let ev = MeasuredEvaluator::new(rt, 1);
    let net = networks::calibnet();
    let cfg = SearchConfig {
        iterations: 14,
        mode: SearchMode::HardwareAware,
        seed: 1,
        ..Default::default()
    };
    let r = search(&ev, &net, &ResourceModel::default(), &DeviceBudget::u250(), &cfg);
    assert_eq!(r.records.len(), 14);
    let best = r.best_record();
    // the dense plan is always reachable, so the best objective must not
    // sacrifice more than a few accuracy points at λ = [0.1, 0.15, 0.1]
    assert!(
        best.accuracy > 55.0,
        "search settled on a broken operating point: {:.1}%",
        best.accuracy
    );
    // and must have found *some* sparsity (natural activation zeros alone
    // give a few percent)
    assert!(best.avg_sparsity > 0.05, "no sparsity found: {}", best.avg_sparsity);
}

#[test]
fn measured_points_feed_dse_and_simulator() {
    if !have_artifacts() {
        return;
    }
    let rt = ModelRuntime::load_default().unwrap();
    let ev = MeasuredEvaluator::new(rt, 1);
    let net = networks::calibnet();
    let n = net.compute_layers().len();
    let plan = PruningPlan::from_unit_point(&vec![0.4; 2 * n], ev.sparsity_model());
    let point = ev.eval(&plan);
    assert_eq!(point.points.len(), n);
    let rm = ResourceModel::default();
    let dev = DeviceBudget::u250();
    let d = explore(&net, &point.points, &rm, &dev, &DseConfig::default());
    assert!(dev.fits(&d.resources));
    let cfgs = stages_from_design(&net, &d.designs, &point.points, rm.fifo_depth);
    let rep = simulate(&net, &cfgs, 3, SparsityDynamics::Deterministic);
    assert!(!rep.deadlocked);
    let ratio = rep.throughput / d.throughput;
    assert!((0.9..1.1).contains(&ratio), "sim/model ratio {ratio}");
}

#[test]
fn runtime_accuracy_reacts_to_real_thresholds() {
    if !have_artifacts() {
        return;
    }
    let rt = ModelRuntime::load_default().unwrap();
    let l = rt.n_layers();
    // thresholds at the 60% weight quantile of each layer, from meta
    let sp = rt.meta.measured_sparsity();
    let tau_w: Vec<f64> = sp.layers.iter().map(|p| p.weight_curve.tau_for(0.6)).collect();
    let tau_a = vec![0.0; l];
    let out = rt.evaluate(&tau_w, &tau_a, 2).unwrap();
    // measured weight sparsity must land near the 60% target per layer
    for (i, &s) in out.s_w.iter().enumerate() {
        assert!((s - 0.6).abs() < 0.08, "layer {i}: S_w {s} (target 0.6)");
    }
    // a trained CalibNet tolerates 60% one-shot pruning reasonably well
    assert!(out.accuracy > 0.5, "accuracy collapsed: {}", out.accuracy);
}

#[test]
fn surrogate_and_measured_paths_share_the_search_machinery() {
    // same geometry, two evaluators — both must run through `search`
    let net = networks::calibnet();
    let cfg = SearchConfig {
        iterations: 6,
        mode: SearchMode::HardwareAware,
        seed: 2,
        ..Default::default()
    };
    let rm = ResourceModel::default();
    let dev = DeviceBudget::u250();
    let sur = SurrogateEvaluator {
        net: net.clone(),
        sparsity: synthesize(&net, 3),
        base_acc: 90.0,
    };
    let r1 = search(&sur, &net, &rm, &dev, &cfg);
    assert_eq!(r1.records.len(), 6);
    if have_artifacts() {
        let rt = ModelRuntime::load_default().unwrap();
        let mev = MeasuredEvaluator::new(rt, 1);
        let r2 = search(&mev, &net, &rm, &dev, &cfg);
        assert_eq!(r2.records.len(), 6);
    }
}

#[test]
fn baselines_and_hass_rank_as_the_paper_claims() {
    // capped device: efficiency differences must show
    let net = networks::calibnet();
    let sp = synthesize(&net, 1);
    let rm = ResourceModel::default();
    let dev = DeviceBudget { dsp: 768, ..DeviceBudget::u250() };
    let dse = DseConfig::default();
    let dense = baselines::dense_dataflow(&net, 90.0, &rm, &dev, &dse);
    let pass = baselines::pass_like(&net, &sp, 90.0, &rm, &dev, &dse);
    let ev = SurrogateEvaluator { net: net.clone(), sparsity: sp, base_acc: 90.0 };
    let cfg = SearchConfig {
        iterations: 24,
        mode: SearchMode::HardwareAware,
        seed: 4,
        ..Default::default()
    };
    let hass_best = search(&ev, &net, &rm, &dev, &cfg);
    let b = hass_best.best_record();
    assert!(
        pass.efficiency > dense.efficiency,
        "activation sparsity must beat dense: {} vs {}",
        pass.efficiency,
        dense.efficiency
    );
    assert!(
        b.efficiency > pass.efficiency,
        "HASS (both axes) must beat PASS (one axis): {} vs {}",
        b.efficiency,
        pass.efficiency
    );
}

#[test]
fn partitioned_resnet50_matches_throughput_model() {
    use hass::dse::partition::{evaluate_bounds, DEFAULT_RECONFIG_SECS};
    let net = networks::resnet50();
    let n = net.compute_layers().len();
    let points = vec![hass::sparsity::SparsityPoint { s_w: 0.5, s_a: 0.4 }; n];
    let rm = ResourceModel::default();
    let dev = DeviceBudget::u250();
    let cfg = DseConfig::default();
    // a hand-picked 2-way split must be feasible on the U250
    let p = evaluate_bounds(
        &net, &points, &rm, &dev, &cfg, &[0, n / 2, n], 4096, DEFAULT_RECONFIG_SECS,
    )
    .expect("2-way split fits");
    assert_eq!(p.n_partitions(), 2);
    for d in &p.designs {
        assert!(dev.fits(&d.resources));
    }
    // end-to-end rate must respect the per-partition bound
    let slowest = p
        .designs
        .iter()
        .map(|d| d.images_per_sec(&dev))
        .fold(f64::INFINITY, f64::min);
    assert!(p.images_per_sec <= slowest * 1.0001);
}

#[test]
fn end_to_end_deterministic_reproducibility() {
    // the whole surrogate pipeline, twice, bit-identical
    let run = || {
        let net = networks::resnet18();
        let sp = synthesize(&net, 9);
        let ev = SurrogateEvaluator { net: net.clone(), sparsity: sp, base_acc: 69.75 };
        let cfg = SearchConfig {
            iterations: 10,
            mode: SearchMode::HardwareAware,
            seed: 5,
            ..Default::default()
        };
        let r = search(&ev, &net, &ResourceModel::default(), &DeviceBudget::u250(), &cfg);
        r.records.iter().map(|x| x.objective.to_bits()).collect::<Vec<_>>()
    };
    assert_eq!(run(), run());
}

#[test]
fn dse_design_survives_simulator_stress() {
    // stochastic dynamics + tight FIFOs: no deadlock, bounded slowdown
    let net = networks::calibnet();
    let n = net.compute_layers().len();
    let points = vec![hass::sparsity::SparsityPoint { s_w: 0.6, s_a: 0.5 }; n];
    let rm = ResourceModel::default();
    let dev = DeviceBudget::u250();
    let d = explore(&net, &points, &rm, &dev, &DseConfig::default());
    let model = network_throughput(&net, &d.designs, &points);
    for seed in [1u64, 2, 3] {
        let mut cfgs = stages_from_design(&net, &d.designs, &points, 64);
        for c in cfgs.iter_mut() {
            c.fifo_capacity = (c.design.o_par as u64 * 4).max(16);
        }
        let rep = simulate(&net, &cfgs, 3, SparsityDynamics::Stochastic { seed });
        assert!(!rep.deadlocked, "seed {seed} deadlocked");
        assert!(
            rep.throughput > model * 0.3,
            "seed {seed}: stochastic collapse {} vs {model}",
            rep.throughput
        );
    }
}
