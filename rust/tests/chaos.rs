//! Chaos suite: the fault-tolerance tentpole under deterministic,
//! seeded fault injection (`util::fault`).
//!
//! The invariant every test here leans on is that the engine's
//! determinism contract *extends to faulty runs*: a fixed [`FaultPlan`]
//! makes injected transient failures and stalls a pure function of the
//! fault seed and the pruning plan, so chaos journals stay bit-identical
//! across thread counts and across the sync / async pipelines — and a
//! faulty run whose retry budget covers the fault budget journals
//! bit-identically to a run with no faults at all.

use hass::arch::networks;
use hass::coordinator::{
    search_sharded, search_sharded_with_cache_ctrl, search_with_cache_ctrl,
    CandidateEvaluator, Checkpoint, CheckpointSpec, DesignCache, EngineConfig, EvalPoint,
    RetryPolicy, SearchConfig, SearchControl, SearchProgress, SearchResult,
    INFEASIBLE_OBJECTIVE,
};
use hass::dse::DseConfig;
use hass::hardware::device::DeviceBudget;
use hass::hardware::resources::ResourceModel;
use hass::pruning::PruningPlan;
use hass::sparsity::{synthesize, NetworkSparsity};
use hass::util::fault::{self, FaultPlan, FaultyEvaluator};

/// Same deterministic stub the tier-1 suite pins the engine with:
/// closed-form quadratic accuracy response, pure and cheap.
struct StubEvaluator {
    sparsity: NetworkSparsity,
}

impl StubEvaluator {
    fn calibnet(seed: u64) -> Self {
        StubEvaluator { sparsity: synthesize(&networks::calibnet(), seed) }
    }
}

impl CandidateEvaluator for StubEvaluator {
    fn sparsity_model(&self) -> &NetworkSparsity {
        &self.sparsity
    }

    fn eval(&self, plan: &PruningPlan) -> EvalPoint {
        let points = plan.points(&self.sparsity);
        let s = points.iter().map(|p| (p.s_w + p.s_a) * 0.5).sum::<f64>()
            / points.len() as f64;
        EvalPoint { accuracy: 92.0 - 30.0 * s * s, points, sim: Vec::new() }
    }

    fn base_accuracy(&self) -> f64 {
        92.0
    }
}

fn chaos_cfg(iters: usize, seed: u64, threads: usize, async_eval: bool) -> SearchConfig {
    SearchConfig {
        iterations: iters,
        seed,
        dse: DseConfig { max_iters: 1_500, ..Default::default() },
        engine: EngineConfig { batch: 4, threads, cache: true, quant_bits: 12, async_eval },
        // fast test cadence; the budget (3) covers every fault plan below
        retry: RetryPolicy { max_retries: 3, base_backoff_ms: 1, max_backoff_ms: 4 },
        ..Default::default()
    }
}

fn objective_bits(r: &SearchResult) -> Vec<u64> {
    r.records.iter().map(|x| x.objective.to_bits()).collect()
}

/// One single-device run through the ctrl entry point with a fresh cache.
fn run_ctrl(
    ev: &dyn CandidateEvaluator,
    cfg: &SearchConfig,
    ctrl: &SearchControl<'_>,
) -> Option<SearchResult> {
    let net = networks::calibnet();
    let rm = ResourceModel::default();
    let dev = DeviceBudget::u250();
    let cache = DesignCache::new();
    search_with_cache_ctrl(ev, &net, &rm, &dev, cfg, &cache, ctrl)
}

/// Every candidate fails transiently (up to twice) before succeeding; a
/// retry budget covering the fault budget must recover every one, so
/// the journal is bit-identical to the zero-fault run — on the sync and
/// async pipelines, serial and pooled.
#[test]
fn retried_faults_leave_the_journal_bit_identical_to_a_clean_run() {
    let ctrl = SearchControl::default();
    let clean_ev = StubEvaluator::calibnet(80);
    let clean = run_ctrl(&clean_ev, &chaos_cfg(12, 25, 0, false), &ctrl).unwrap();
    assert_eq!(clean.stats.retried_evals, 0);
    let fp = FaultPlan { seed: 7, fail_rate: 1.0, max_failures: 2, stall_rate: 0.0 };
    for (threads, async_eval) in [(1, false), (0, false), (1, true), (0, true)] {
        let inner = StubEvaluator::calibnet(80);
        let faulty = FaultyEvaluator::new(&inner, fp);
        let cfg = chaos_cfg(12, 25, threads, async_eval);
        let r = run_ctrl(&faulty, &cfg, &ctrl).unwrap();
        assert!(
            r.stats.retried_evals > 0,
            "threads={threads} async={async_eval}: a fail_rate-1.0 plan must retry"
        );
        assert_eq!(
            objective_bits(&clean),
            objective_bits(&r),
            "threads={threads} async={async_eval}: recovered chaos journal diverged"
        );
        for (a, b) in clean.records.iter().zip(&r.records) {
            assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
            assert_eq!(a.plan, b.plan);
        }
        assert_eq!(clean.best, r.best);
    }
}

/// A retry budget *smaller* than the fault budget leaves some candidates
/// permanently failed — deterministically: which ones is a pure function
/// of the fault seed, so runs agree bit for bit across thread counts,
/// and every journal line stays finite.
#[test]
fn an_exhausted_retry_budget_fails_candidates_deterministically() {
    let ctrl = SearchControl::default();
    let fp = FaultPlan { seed: 13, fail_rate: 1.0, max_failures: 2, stall_rate: 0.0 };
    let run = |threads: usize| {
        let inner = StubEvaluator::calibnet(83);
        let faulty = FaultyEvaluator::new(&inner, fp);
        let mut cfg = chaos_cfg(10, 37, threads, false);
        cfg.retry = RetryPolicy { max_retries: 1, base_backoff_ms: 1, max_backoff_ms: 2 };
        run_ctrl(&faulty, &cfg, &ctrl).unwrap()
    };
    let a = run(1);
    let b = run(0);
    assert_eq!(a.records.len(), 10);
    assert_eq!(
        objective_bits(&a),
        objective_bits(&b),
        "exhausted-budget journal diverged across thread counts"
    );
    assert_eq!(a.stats.retried_evals, b.stats.retried_evals);
    for rec in &a.records {
        assert!(rec.objective.is_finite(), "iter {}: non-finite objective", rec.iter);
    }
}

/// Watchdog reclamation: an async evaluator that never delivers any
/// completion must not hang the search — `eval_timeout_ms` (and,
/// equivalently, `deadline_ms`) reclaims every in-flight slot as an
/// infeasible-scored record, and the journal is identical whichever
/// watchdog fires and however many worker threads run.
#[test]
fn stalled_measurements_are_reclaimed_infeasible_not_hung() {
    let ctrl = SearchControl::default();
    let fp = FaultPlan { seed: 21, fail_rate: 0.0, max_failures: 0, stall_rate: 1.0 };
    let run = |threads: usize, eval_timeout_ms: u64, deadline_ms: u64| {
        let inner = StubEvaluator::calibnet(84);
        let faulty = FaultyEvaluator::new(&inner, fp);
        let mut cfg = chaos_cfg(10, 41, threads, true);
        cfg.eval_timeout_ms = eval_timeout_ms;
        cfg.deadline_ms = deadline_ms;
        run_ctrl(&faulty, &cfg, &ctrl).unwrap()
    };
    let a = run(1, 150, 0);
    assert_eq!(a.records.len(), 10, "stalls must not shorten the journal");
    assert_eq!(a.stats.reclaimed_stalls, 10, "every measurement stalls here");
    for rec in &a.records {
        assert_eq!(rec.objective, INFEASIBLE_OBJECTIVE, "iter {}", rec.iter);
        assert_eq!(rec.accuracy, 0.0);
    }
    let b = run(0, 150, 0);
    assert_eq!(objective_bits(&a), objective_bits(&b));
    assert_eq!(b.stats.reclaimed_stalls, 10);
    // the per-generation deadline reclaims the same set
    let c = run(0, 0, 300);
    assert_eq!(objective_bits(&a), objective_bits(&c));
}

/// Partial stalls: reclaimed slots and healthy completions mix inside a
/// generation, the infeasible-record count matches the reclaim counter
/// exactly, and the mix is thread-count invariant (stall selection is a
/// pure function of the fault seed).
#[test]
fn a_partial_stall_mix_is_deterministic_across_thread_counts() {
    let ctrl = SearchControl::default();
    let fp = FaultPlan { seed: 33, fail_rate: 0.0, max_failures: 0, stall_rate: 0.4 };
    let run = |threads: usize| {
        let inner = StubEvaluator::calibnet(85);
        let faulty = FaultyEvaluator::new(&inner, fp);
        let mut cfg = chaos_cfg(12, 43, threads, true);
        cfg.eval_timeout_ms = 150;
        run_ctrl(&faulty, &cfg, &ctrl).unwrap()
    };
    let a = run(1);
    let b = run(0);
    assert_eq!(objective_bits(&a), objective_bits(&b), "partial-stall journal diverged");
    assert_eq!(a.stats.reclaimed_stalls, b.stats.reclaimed_stalls);
    let infeasible =
        a.records.iter().filter(|r| r.objective == INFEASIBLE_OBJECTIVE).count() as u64;
    assert_eq!(
        infeasible, a.stats.reclaimed_stalls,
        "reclaim counter must match the infeasible journal lines"
    );
}

/// The checkpoint/resume tentpole: cancel a checkpointed sharded search
/// mid-run (as a daemon shutdown or SIGKILL-then-rerun would), resume
/// from the file it left behind, and the continued journals are
/// bit-identical to an uninterrupted run on every device.
#[test]
fn a_cancelled_checkpointed_search_resumes_bit_identically() {
    let net = networks::calibnet();
    let rm = ResourceModel::default();
    let devices = [DeviceBudget::u250(), DeviceBudget::v7_690t()];
    let ev = StubEvaluator::calibnet(82);
    let baseline = search_sharded(&ev, &net, &rm, &devices, &chaos_cfg(12, 29, 0, false));

    let path = std::env::temp_dir().join("hass_chaos_resume_test.json");
    std::fs::remove_file(&path).ok();
    let ckpt_path = path.to_str().unwrap().to_string();
    let mut cfg = chaos_cfg(12, 29, 0, false);
    cfg.checkpoint = Some(CheckpointSpec { path: ckpt_path.clone(), every: 1 });
    // cancel once 8 of 12 iterations are done (a generation boundary)
    let observer = |p: SearchProgress| p.done < 8;
    let ctrl = SearchControl { observer: Some(&observer), ..Default::default() };
    let cache = DesignCache::new();
    let cancelled =
        search_sharded_with_cache_ctrl(&ev, &net, &rm, &devices, &cfg, &cache, &ctrl);
    assert!(cancelled.is_none(), "the observer must cancel the run");

    let ck = Checkpoint::load(&ckpt_path).expect("cancellation must leave a checkpoint");
    assert_eq!(ck.done, 8);
    assert_eq!(ck.devices.len(), devices.len());
    let rctrl = SearchControl { resume: Some(&ck), ..Default::default() };
    let cache2 = DesignCache::new();
    let resumed =
        search_sharded_with_cache_ctrl(&ev, &net, &rm, &devices, &cfg, &cache2, &rctrl)
            .expect("resumed run must complete");
    std::fs::remove_file(&path).ok();

    for (a, b) in baseline.per_device.iter().zip(&resumed.per_device) {
        assert_eq!(a.device, b.device);
        assert_eq!(a.result.records.len(), b.result.records.len());
        for (x, y) in a.result.records.iter().zip(&b.result.records) {
            assert_eq!(
                x.objective.to_bits(),
                y.objective.to_bits(),
                "{} iter {}: resumed journal diverged from the uninterrupted run",
                a.device,
                x.iter
            );
            assert_eq!(x.accuracy.to_bits(), y.accuracy.to_bits());
            assert_eq!(x.images_per_sec.to_bits(), y.images_per_sec.to_bits());
            assert_eq!(x.plan, y.plan);
        }
        assert_eq!(a.result.best, b.result.best);
    }
}

/// A checkpoint from a *different* search (wrong fingerprint) is ignored
/// at the engine layer — the run silently starts fresh instead of
/// replaying foreign records (the CLI refuses loudly before it gets
/// here; the engine is the backstop).
#[test]
fn a_foreign_checkpoint_is_ignored_and_the_search_starts_fresh() {
    let ev = StubEvaluator::calibnet(86);
    let cfg = chaos_cfg(8, 47, 0, false);
    let ctrl = SearchControl::default();
    let fresh = run_ctrl(&ev, &cfg, &ctrl).unwrap();
    let bogus = Checkpoint { fingerprint: 0xdead_beef, done: 4, devices: Vec::new() };
    let rctrl = SearchControl { resume: Some(&bogus), ..Default::default() };
    let resumed = run_ctrl(&ev, &cfg, &rctrl).unwrap();
    assert_eq!(
        objective_bits(&fresh),
        objective_bits(&resumed),
        "a mismatched checkpoint must not perturb the search"
    );
}

/// Checkpoint writes are best-effort: an injected IO fault at the
/// `ckpt.save` site costs a warning, never the search — and the faulted
/// write leaves no file behind (saves are atomic).
#[test]
fn an_injected_checkpoint_io_fault_never_kills_a_healthy_search() {
    let _x = fault::exclusive();
    let ev = StubEvaluator::calibnet(87);
    let ctrl = SearchControl::default();
    let clean = run_ctrl(&ev, &chaos_cfg(8, 53, 0, false), &ctrl).unwrap();
    let path = std::env::temp_dir().join("hass_chaos_ckpt_fault_test.json");
    std::fs::remove_file(&path).ok();
    let mut cfg = chaos_cfg(8, 53, 0, false);
    let ckpt_path = path.to_str().unwrap().to_string();
    cfg.checkpoint = Some(CheckpointSpec { path: ckpt_path, every: 1 });
    let _g = fault::armed("ckpt.save", 1);
    // 8 iterations / batch 4 = 2 generations: exactly one mid-run
    // checkpoint write, and it is the one that faults
    let r = run_ctrl(&ev, &cfg, &ctrl)
        .expect("a failed checkpoint write must not kill the search");
    assert_eq!(
        objective_bits(&clean),
        objective_bits(&r),
        "checkpointing (even failing checkpointing) must never change results"
    );
    assert!(!path.exists(), "the faulted write must not leave a file behind");
    std::fs::remove_file(&path).ok();
}

/// Zero-fault runs with every fault-tolerance knob enabled journal
/// bit-identically to the plain configuration: retry budgets, watchdog
/// timeouts and checkpoint cadence are execution knobs outside the
/// determinism fingerprint.
#[test]
fn fault_tolerance_knobs_cost_nothing_on_a_healthy_run() {
    let ev = StubEvaluator::calibnet(88);
    let ctrl = SearchControl::default();
    let plain = run_ctrl(&ev, &chaos_cfg(10, 59, 0, false), &ctrl).unwrap();
    let path = std::env::temp_dir().join("hass_chaos_knob_test.json");
    std::fs::remove_file(&path).ok();
    let mut cfg = chaos_cfg(10, 59, 0, true);
    cfg.retry = RetryPolicy { max_retries: 5, base_backoff_ms: 1, max_backoff_ms: 8 };
    cfg.eval_timeout_ms = 5_000;
    cfg.deadline_ms = 60_000;
    let ckpt_path = path.to_str().unwrap().to_string();
    cfg.checkpoint = Some(CheckpointSpec { path: ckpt_path, every: 1 });
    let armored = run_ctrl(&ev, &cfg, &ctrl).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(
        objective_bits(&plain),
        objective_bits(&armored),
        "fault-tolerance knobs changed a healthy run's journal"
    );
    assert_eq!(armored.stats.retried_evals, 0);
    assert_eq!(armored.stats.reclaimed_stalls, 0);
}

/// The pipelined twin of the resume tentpole: kill a depth-2 lookahead
/// search mid-run — with generations in flight past the last observed
/// one — resume from the checkpoint, and the finished journals are
/// bit-identical to an uninterrupted depth-2 run on every device.  The
/// checkpoint records only *reduced* generations; the replay regenerates
/// the lookahead proposal schedule (same optimizer RNG trace), so no
/// pipeline state needs to survive the kill.
#[test]
fn a_mid_pipeline_checkpoint_resumes_bit_identically() {
    let net = networks::calibnet();
    let rm = ResourceModel::default();
    let devices = [DeviceBudget::u250(), DeviceBudget::v7_690t()];
    let ev = StubEvaluator::calibnet(89);
    let mut base_cfg = chaos_cfg(16, 61, 0, false);
    base_cfg.pipeline_depth = 2;
    let baseline = search_sharded(&ev, &net, &rm, &devices, &base_cfg);
    assert!(baseline.stats.pipelined_generations > 0, "the baseline must pipeline");

    let path = std::env::temp_dir().join("hass_chaos_pipeline_resume_test.json");
    std::fs::remove_file(&path).ok();
    let ckpt_path = path.to_str().unwrap().to_string();
    let mut cfg = base_cfg.clone();
    cfg.checkpoint = Some(CheckpointSpec { path: ckpt_path.clone(), every: 1 });
    // cancel once 8 of 16 iterations are reduced — at depth 2, up to two
    // further generations are in flight at that moment and are discarded
    let observer = |p: SearchProgress| p.done < 8;
    let ctrl = SearchControl { observer: Some(&observer), ..Default::default() };
    let cache = DesignCache::new();
    let cancelled =
        search_sharded_with_cache_ctrl(&ev, &net, &rm, &devices, &cfg, &cache, &ctrl);
    assert!(cancelled.is_none(), "the observer must cancel the run");

    let ck = Checkpoint::load(&ckpt_path).expect("cancellation must leave a checkpoint");
    assert_eq!(ck.done, 8, "checkpoints land on reduced-generation boundaries only");
    let rctrl = SearchControl { resume: Some(&ck), ..Default::default() };
    let cache2 = DesignCache::new();
    let resumed =
        search_sharded_with_cache_ctrl(&ev, &net, &rm, &devices, &cfg, &cache2, &rctrl)
            .expect("resumed run must complete");
    std::fs::remove_file(&path).ok();

    // replayed lookahead draws count too: the proposal schedule is a pure
    // function of the depth, so the counter is kill/resume invariant
    assert_eq!(resumed.stats.lookahead_proposals, baseline.stats.lookahead_proposals);
    for (a, b) in baseline.per_device.iter().zip(&resumed.per_device) {
        assert_eq!(a.device, b.device);
        assert_eq!(a.result.records.len(), b.result.records.len());
        for (x, y) in a.result.records.iter().zip(&b.result.records) {
            assert_eq!(
                x.objective.to_bits(),
                y.objective.to_bits(),
                "{} iter {}: mid-pipeline resume diverged from the uninterrupted run",
                a.device,
                x.iter
            );
            assert_eq!(x.accuracy.to_bits(), y.accuracy.to_bits());
            assert_eq!(x.images_per_sec.to_bits(), y.images_per_sec.to_bits());
            assert_eq!(x.plan, y.plan);
        }
        assert_eq!(a.result.best, b.result.best);
    }
}
