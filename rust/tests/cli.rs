//! Binary-level CLI tests: the built `hass` executable, driven as a
//! user would drive it.  The satellite contract under test: malformed
//! *input* never panics — bad flag values exit 2 with the error and
//! usage on stderr, unwritable output paths exit 1 with a message, and
//! degenerate-but-legal inputs (`--iters 0`) succeed.

use std::process::{Command, Output};

fn hass(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_hass"))
        .args(args)
        .output()
        .expect("run hass binary")
}

fn stderr_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

#[test]
fn malformed_flag_value_exits_2_with_usage_not_a_panic() {
    let out = hass(&["search", "--iters=abc"]);
    assert_eq!(out.status.code(), Some(2));
    let err = stderr_of(&out);
    assert!(
        err.contains("--iters") && err.contains("abc"),
        "error must name the flag and the bad value: {err}"
    );
    assert!(err.contains("options:"), "usage must be printed: {err}");
    assert!(!err.contains("panicked"), "panic leaked to the user: {err}");
}

#[test]
fn malformed_values_never_panic_across_subcommands() {
    for args in [
        &["search", "--seed", "1.5"][..],
        &["search", "--batch=-2"][..],
        &["dse", "--sw=half"][..],
        &["simulate", "--images", "lots"][..],
        &["partition", "--batch", "x"][..],
    ] {
        let out = hass(args);
        assert_eq!(out.status.code(), Some(2), "{args:?}");
        let err = stderr_of(&out);
        assert!(!err.contains("panicked"), "{args:?} panicked: {err}");
        assert!(err.contains("expects"), "{args:?}: unhelpful error: {err}");
    }
}

#[test]
fn unknown_option_and_unknown_device_exit_2() {
    let out = hass(&["search", "--nonsense", "1"]);
    assert_eq!(out.status.code(), Some(2));
    assert!(stderr_of(&out).contains("unknown option"));
    let out = hass(&["search", "--device", "tpu"]);
    assert_eq!(out.status.code(), Some(2));
    assert!(stderr_of(&out).contains("unknown device"));
}

#[test]
fn zero_iteration_search_exits_cleanly() {
    let out = hass(&["search", "--iters", "0", "--evaluator", "surrogate"]);
    assert_eq!(
        out.status.code(),
        Some(0),
        "--iters 0 is a legal smoke run; stderr: {}",
        stderr_of(&out)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("no iterations run"), "missing notice: {stdout}");
}

#[test]
fn unwritable_journal_path_exits_1_gracefully() {
    // the journal's parent "directory" is an existing file
    let blocker = std::env::temp_dir().join("hass_cli_journal_blocker");
    std::fs::write(&blocker, "occupied").expect("create blocker file");
    let journal = blocker.join("j.csv");
    let out = hass(&[
        "search",
        "--iters",
        "1",
        "--evaluator",
        "surrogate",
        "--journal",
        journal.to_str().expect("utf-8 temp path"),
    ]);
    std::fs::remove_file(&blocker).ok();
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr_of(&out));
    let err = stderr_of(&out);
    assert!(err.contains("failed to write journal"), "unhelpful error: {err}");
    assert!(!err.contains("panicked"), "panic leaked to the user: {err}");
}

#[test]
fn client_without_a_daemon_fails_gracefully() {
    // a port nobody listens on: connect fails, exit 1, helpful hint
    // (--connect-retries 0 pins the no-retry path and keeps this fast)
    let out = hass(&["client", "stats", "--addr", "127.0.0.1:1", "--connect-retries", "0"]);
    assert_eq!(out.status.code(), Some(1));
    let err = stderr_of(&out);
    assert!(err.contains("failed to connect"), "unhelpful error: {err}");
    assert!(!err.contains("retry"), "--connect-retries 0 must not retry: {err}");
    assert!(!err.contains("panicked"), "panic leaked to the user: {err}");
}

#[test]
fn client_reconnects_with_bounded_backoff_before_giving_up() {
    let out = hass(&["client", "stats", "--addr", "127.0.0.1:1", "--connect-retries", "2"]);
    assert_eq!(out.status.code(), Some(1), "exhausted retries still exit 1");
    let err = stderr_of(&out);
    assert!(err.contains("retry 1 of 2"), "first retry must be reported: {err}");
    assert!(err.contains("retry 2 of 2"), "second retry must be reported: {err}");
    assert!(err.contains("failed to connect"), "final error must still print: {err}");
    assert!(!err.contains("panicked"), "panic leaked to the user: {err}");
}

#[test]
fn resume_refuses_a_missing_checkpoint() {
    let out = hass(&[
        "search",
        "--iters",
        "4",
        "--evaluator",
        "surrogate",
        "--resume",
        "/nonexistent/hass_ckpt.json",
    ]);
    assert_eq!(out.status.code(), Some(2));
    let err = stderr_of(&out);
    assert!(err.contains("failed to load checkpoint"), "unhelpful error: {err}");
    assert!(!err.contains("panicked"), "panic leaked to the user: {err}");
}

#[test]
fn checkpointed_search_resumes_only_a_matching_run() {
    let ckpt = std::env::temp_dir().join("hass_cli_resume_test.json");
    std::fs::remove_file(&ckpt).ok();
    let ckpt_s = ckpt.to_str().expect("utf-8 temp path");
    // 8 iters / batch 4 = 2 generations: the mid-run checkpoint at
    // iteration 4 stays on disk after the run completes
    let base = ["search", "--iters", "8", "--batch", "4", "--evaluator", "surrogate"];
    let mut write = base.to_vec();
    write.extend(["--seed", "5", "--checkpoint", ckpt_s]);
    let out = hass(&write);
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr_of(&out));
    assert!(ckpt.exists(), "mid-run checkpoint must be left on disk");
    // a different seed is a different search: refuse loudly, exit 2
    let mut foreign = base.to_vec();
    foreign.extend(["--seed", "6", "--resume", ckpt_s]);
    let out = hass(&foreign);
    assert_eq!(out.status.code(), Some(2));
    let err = stderr_of(&out);
    assert!(err.contains("refusing to resume"), "unhelpful error: {err}");
    // the matching configuration resumes and completes
    let mut resume = base.to_vec();
    resume.extend(["--seed", "5", "--resume", ckpt_s]);
    let out = hass(&resume);
    std::fs::remove_file(&ckpt).ok();
    assert_eq!(out.status.code(), Some(0), "stderr: {}", stderr_of(&out));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("resume <-"), "resume notice missing: {stdout}");
}
