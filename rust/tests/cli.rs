//! Binary-level CLI tests: the built `hass` executable, driven as a
//! user would drive it.  The satellite contract under test: malformed
//! *input* never panics — bad flag values exit 2 with the error and
//! usage on stderr, unwritable output paths exit 1 with a message, and
//! degenerate-but-legal inputs (`--iters 0`) succeed.

use std::process::{Command, Output};

fn hass(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_hass"))
        .args(args)
        .output()
        .expect("run hass binary")
}

fn stderr_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

#[test]
fn malformed_flag_value_exits_2_with_usage_not_a_panic() {
    let out = hass(&["search", "--iters=abc"]);
    assert_eq!(out.status.code(), Some(2));
    let err = stderr_of(&out);
    assert!(
        err.contains("--iters") && err.contains("abc"),
        "error must name the flag and the bad value: {err}"
    );
    assert!(err.contains("options:"), "usage must be printed: {err}");
    assert!(!err.contains("panicked"), "panic leaked to the user: {err}");
}

#[test]
fn malformed_values_never_panic_across_subcommands() {
    for args in [
        &["search", "--seed", "1.5"][..],
        &["search", "--batch=-2"][..],
        &["dse", "--sw=half"][..],
        &["simulate", "--images", "lots"][..],
        &["partition", "--batch", "x"][..],
    ] {
        let out = hass(args);
        assert_eq!(out.status.code(), Some(2), "{args:?}");
        let err = stderr_of(&out);
        assert!(!err.contains("panicked"), "{args:?} panicked: {err}");
        assert!(err.contains("expects"), "{args:?}: unhelpful error: {err}");
    }
}

#[test]
fn unknown_option_and_unknown_device_exit_2() {
    let out = hass(&["search", "--nonsense", "1"]);
    assert_eq!(out.status.code(), Some(2));
    assert!(stderr_of(&out).contains("unknown option"));
    let out = hass(&["search", "--device", "tpu"]);
    assert_eq!(out.status.code(), Some(2));
    assert!(stderr_of(&out).contains("unknown device"));
}

#[test]
fn zero_iteration_search_exits_cleanly() {
    let out = hass(&["search", "--iters", "0", "--evaluator", "surrogate"]);
    assert_eq!(
        out.status.code(),
        Some(0),
        "--iters 0 is a legal smoke run; stderr: {}",
        stderr_of(&out)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("no iterations run"), "missing notice: {stdout}");
}

#[test]
fn unwritable_journal_path_exits_1_gracefully() {
    // the journal's parent "directory" is an existing file
    let blocker = std::env::temp_dir().join("hass_cli_journal_blocker");
    std::fs::write(&blocker, "occupied").expect("create blocker file");
    let journal = blocker.join("j.csv");
    let out = hass(&[
        "search",
        "--iters",
        "1",
        "--evaluator",
        "surrogate",
        "--journal",
        journal.to_str().expect("utf-8 temp path"),
    ]);
    std::fs::remove_file(&blocker).ok();
    assert_eq!(out.status.code(), Some(1), "stderr: {}", stderr_of(&out));
    let err = stderr_of(&out);
    assert!(err.contains("failed to write journal"), "unhelpful error: {err}");
    assert!(!err.contains("panicked"), "panic leaked to the user: {err}");
}

#[test]
fn client_without_a_daemon_fails_gracefully() {
    // a port nobody listens on: connect fails, exit 1, helpful hint
    let out = hass(&["client", "stats", "--addr", "127.0.0.1:1"]);
    assert_eq!(out.status.code(), Some(1));
    let err = stderr_of(&out);
    assert!(err.contains("failed to connect"), "unhelpful error: {err}");
    assert!(!err.contains("panicked"), "panic leaked to the user: {err}");
}
