"""Synthetic 10-class image dataset (ImageNet substitute, DESIGN.md §1).

Calibration in HASS only needs (a) input-dependent activation statistics
and (b) a non-trivial accuracy response to pruning.  This procedural
dataset provides both, deterministically: each class is a superposition of
an oriented grating (class-specific angle/frequency), a class-colored
Gaussian blob at a class-biased location, and per-sample nuisance
(phase, amplitude, position jitter, additive noise), so the network must
learn oriented-frequency and color-location features — pruning those
features degrades accuracy smoothly and then sharply, like Fig. 1.
"""

import numpy as np

from . import common


def make_dataset(n, seed):
    """Generate n labelled images.

    Returns:
      images: (n, 32, 32, 3) f32, roughly zero-mean unit-range
      labels: (n,) i32 in [0, 10)
    """
    rng = np.random.default_rng(seed)
    s = common.IMG_SIZE
    yy, xx = np.meshgrid(np.arange(s), np.arange(s), indexing="ij")
    yy = yy.astype(np.float32)
    xx = xx.astype(np.float32)

    labels = rng.integers(0, common.NUM_CLASSES, size=n).astype(np.int32)
    images = np.empty((n, s, s, 3), dtype=np.float32)

    for i in range(n):
        c = int(labels[i])
        # --- oriented grating: angle and frequency are class features
        theta = np.pi * c / common.NUM_CLASSES + rng.normal(0, 0.12)
        freq = (2.0 + (c % 3)) * (2 * np.pi / s) * rng.uniform(0.9, 1.1)
        phase = rng.uniform(0, 2 * np.pi)
        amp = rng.uniform(0.6, 1.0)
        grating = amp * np.sin(
            freq * (xx * np.cos(theta) + yy * np.sin(theta)) + phase
        )
        # --- class-colored blob at a class-biased location
        cx = s * (0.25 + 0.5 * ((c * 7) % 10) / 9.0) + rng.normal(0, 2.0)
        cy = s * (0.25 + 0.5 * ((c * 3) % 10) / 9.0) + rng.normal(0, 2.0)
        sig = rng.uniform(3.0, 5.0)
        blob = np.exp(-((xx - cx) ** 2 + (yy - cy) ** 2) / (2 * sig * sig))
        color = np.array(
            [
                0.5 + 0.5 * np.cos(2 * np.pi * c / 10.0),
                0.5 + 0.5 * np.cos(2 * np.pi * c / 10.0 + 2.1),
                0.5 + 0.5 * np.cos(2 * np.pi * c / 10.0 + 4.2),
            ],
            dtype=np.float32,
        )
        img = (
            0.6 * grating[..., None] * rng.uniform(0.4, 1.0, size=3).astype(np.float32)
            + 0.9 * blob[..., None] * color
        )
        img += rng.normal(0, 0.55, size=img.shape).astype(np.float32)
        # occasional distractor blob (wrong color, random spot) to create
        # genuine class confusions under feature loss
        if rng.random() < 0.5:
            dx_, dy_ = rng.uniform(4, 28, size=2)
            dsig = rng.uniform(2.0, 4.0)
            dblob = np.exp(-((xx - dx_) ** 2 + (yy - dy_) ** 2) / (2 * dsig * dsig))
            img += 0.6 * dblob[..., None] * rng.uniform(0, 1, size=3).astype(np.float32)
        img += rng.uniform(-0.2, 0.2)  # global offset nuisance
        images[i] = img

    # normalize to zero mean / unit std over the whole set (deterministic
    # given the seed; the constants are stored implicitly in the data).
    images -= images.mean()
    images /= images.std() + 1e-8
    return images, labels


def train_val(seed=20240731, n_train=8192, n_val=2048):
    """The canonical artifact-build split (val doubles as calibration)."""
    train = make_dataset(n_train, seed)
    val = make_dataset(n_val, seed + 1)
    return train, val
