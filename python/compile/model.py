"""L2 — the CalibNet forward pass (JAX), routed through the L1 SPE kernel.

This is the computation the Rust coordinator executes on every TPE
iteration.  The per-layer clip thresholds are *runtime inputs*, so a single
AOT artifact serves the whole search — Python is never on the search path.

Signature of the exported function (see `aot.py`):

    f(images, w0, b0, ..., w9, b9, tau_w[10], tau_a[10])
        -> (logits[B,10], S_w[10], S_a[10], pair_density[10])

where S_w/S_a are the measured post-clip zero fractions (the paper's
sparsity statistics) and pair_density[l] = nnz_pairs / (M*K*N) is the
(1 - S̄_l) that parameterizes the SPE cycle model (Eq. 1).
"""

import jax
import jax.numpy as jnp

from . import common
from .kernels import ref, spe


def fxp_quantize(v):
    """Fake-quantize to the paper's 16-bit fixed point (Q8.8)."""
    q = jnp.round(v * common.FXP_SCALE) / common.FXP_SCALE
    return jnp.clip(q, common.FXP_MIN, common.FXP_MAX)


def im2col(x, spec):
    """Unrolled patch extraction for a conv layer.

    x: (B, H, W, C) -> (B * Ho * Wo, kh * kw * C), ordered so that
    w.reshape(kh*kw*C, cout) contracts correctly (row-major (dy, dx),
    channel fastest) — property-tested against lax.conv in test_model.py.
    """
    k, s, p = spec.kernel, spec.stride, spec.pad
    b = x.shape[0]
    ho = wo = spec.out_hw
    if p > 0:
        x = jnp.pad(x, ((0, 0), (p, p), (p, p), (0, 0)))
    cols = []
    for dy in range(k):
        for dx in range(k):
            cols.append(
                x[:, dy : dy + s * (ho - 1) + 1 : s, dx : dx + s * (wo - 1) + 1 : s, :]
            )
    patches = jnp.concatenate(cols, axis=-1)  # (B, Ho, Wo, k*k*C)
    return patches.reshape(b * ho * wo, k * k * spec.cin)


def _layer(idx, x, w, b, tau_w, tau_a, *, quantize, block_m, use_pallas):
    """Run prunable layer `idx` on activation tensor x.

    Returns (pre-activation output tensor, (s_w, s_a, pair_density)).
    """
    spec = common.LAYERS[idx]
    if quantize:
        x = fxp_quantize(x)
    tw = tau_w[idx]
    ta = tau_a[idx]
    if spec.kind == "linear":
        patches = x  # (B, cin)
        w2d = w  # (cin, cout)
        out_hw = None
    else:
        patches = im2col(x, spec)
        w2d = w.reshape(spec.patch_k(), spec.cout)
        out_hw = spec.out_hw
    if use_pallas:
        out, nnz = spe.spe_matmul(patches, w2d, tw, ta, block_m=block_m)
    else:
        out, nnz = ref.spe_matmul_ref(patches, w2d, tw, ta)
    m = patches.shape[0]
    total_pairs = m * patches.shape[1] * spec.cout
    pair_density = nnz / total_pairs
    # The paper's S_a is measured on the activation *tensor* (the data
    # crossing the layer interface), S_w on the weight tensor.
    s_a = ref.sparsity(ref.clip_magnitude(x, ta))
    s_w = ref.sparsity(ref.clip_magnitude(w2d, tw))
    out = out + b
    if out_hw is not None:
        bsz = x.shape[0]
        out = out.reshape(bsz, out_hw, out_hw, spec.cout)
    return out, (s_w, s_a, pair_density)


def forward(params, images, tau_w, tau_a, *, quantize=True,
            block_m=spe.DEFAULT_BLOCK_M, use_pallas=True):
    """CalibNet forward with per-layer clip thresholds.

    Args:
      params: list of 10 (w, b) tuples in `common.LAYERS` order (BN already
        folded — see train.py).
      images: (B, 32, 32, 3) f32.
      tau_w, tau_a: (10,) f32 absolute clip thresholds.
      quantize: apply Q8.8 fake quantization to activations (weights are
        quantized once at export time).
      use_pallas: route matmuls through the Pallas SPE kernel (True for the
        AOT artifact) or the jnp oracle (False; used in tests).

    Returns:
      logits (B, 10), s_w (10,), s_a (10,), pair_density (10,)
    """
    assert len(params) == common.NUM_LAYERS
    kw = dict(quantize=quantize, block_m=block_m, use_pallas=use_pallas)
    stats = [None] * common.NUM_LAYERS

    def run(idx, x):
        w, b = params[idx]
        out, st = _layer(idx, x, w, b, tau_w, tau_a, **kw)
        stats[idx] = st
        return out

    x = run(0, images)
    x = jax.nn.relu(x)
    # block 1 (identity shortcut)
    h = jax.nn.relu(run(1, x))
    x = jax.nn.relu(run(2, h) + x)
    # block 2 (projection shortcut, stride 2)
    h = jax.nn.relu(run(3, x))
    x = jax.nn.relu(run(4, h) + run(5, x))
    # block 3 (projection shortcut, stride 2)
    h = jax.nn.relu(run(6, x))
    x = jax.nn.relu(run(7, h) + run(8, x))
    # global average pool + classifier
    x = jnp.mean(x, axis=(1, 2))  # (B, 64)
    logits = run(9, x)

    s_w = jnp.stack([s[0] for s in stats])
    s_a = jnp.stack([s[1] for s in stats])
    dens = jnp.stack([s[2] for s in stats])
    return logits, s_w, s_a, dens


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
