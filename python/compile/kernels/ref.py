"""Pure-jnp oracle for the SPE kernel — the CORE correctness signal.

Everything here is written in the most obvious way possible (boolean
matmul pair counting included) so it can serve as ground truth for the
Pallas kernel in `spe.py` under pytest/hypothesis sweeps.
"""

import jax.numpy as jnp


def clip_magnitude(v, tau):
    """Zero out any value whose magnitude is strictly below tau."""
    return jnp.where(jnp.abs(v) >= tau, v, jnp.zeros_like(v))


def spe_matmul_ref(x, w, tau_w, tau_a):
    """Reference thresholded sparse matmul.

    Mirrors `spe.spe_matmul`: returns (clip(x) @ clip(w), nnz_pair_count).
    The pair count is computed with an explicit boolean contraction —
    deliberately naive.
    """
    xc = clip_magnitude(x, tau_a)
    wc = clip_magnitude(w, tau_w)
    out = xc @ wc
    xm = (xc != 0.0).astype(jnp.float32)  # (M, K)
    wm = (wc != 0.0).astype(jnp.float32)  # (K, N)
    # pair (m, k, n) counted iff x[m,k] != 0 and w[k,n] != 0
    nnz_pairs = jnp.sum(xm @ wm)
    return out, nnz_pairs


def sparsity(v):
    """Fraction of exact zeros in a tensor — the paper's S_w / S_a."""
    return jnp.mean((v == 0.0).astype(jnp.float32))
