"""L1 — Pallas kernel for the HASS Sparse vector dot-Product Engine (SPE).

The paper's SPE (Fig. 3) is an FPGA structure: *clip* modules zero out any
weight/activation whose magnitude falls below a configurable threshold,
*zero-filtering* detects the zeros, non-zero pairs are dispatched to DSP
MACs by a round-robin arbiter, and a dedicated *counter* tracks skipped
zeros so the accumulator knows when a dot product is complete.

TPU adaptation (DESIGN.md §Hardware-Adaptation): there is no per-element
dynamic scheduling on a TPU, but the paper's core insight — density-scaled
work with exact zero bookkeeping — survives.  We tile the im2col'd
convolution as VMEM blocks (BlockSpec plays the role the BRAM→arbiter
schedule plays on the FPGA), apply the clip thresholds inside the tile on
the VPU, count the non-zero pairs per tile (the paper's counter — exactly
the statistic that parameterizes the cycle model Eq. 1), and let the MXU
consume the clipped (hence exactly-sparse) tile.  The *scheduling* benefit
of sparsity — fewer cycles per output — is then realized by the L3 hardware
model precisely as the FPGA arbiter realizes it; this kernel guarantees the
numerics and the statistics are bit-identical to what that hardware
computes.

`interpret=True` always: real-TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot run.  Correctness is pinned against the pure-jnp
oracle in `ref.py` (pytest + hypothesis).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block size along the M (= batch x spatial) dimension.  128 rows of
# f32 activations with K <= 576 keeps the working set (x-block + w + out)
# well under VMEM budgets while remaining MXU-shaped (multiples of 8x128
# lanes); see EXPERIMENTS.md §Perf for the footprint table.
DEFAULT_BLOCK_M = 128


def _spe_kernel(x_ref, w_ref, tw_ref, ta_ref, o_ref, cnt_ref):
    """One grid step: clip -> zero-filter/count -> MAC a (bm, K)x(K, N) tile.

    x_ref:   (bm, K) activation patch tile (VMEM)
    w_ref:   (K, N)  weight tile (VMEM, resident across grid steps)
    tw_ref:  (1, 1)  weight clip threshold (runtime input -> no retrace)
    ta_ref:  (1, 1)  activation clip threshold
    o_ref:   (bm, N) output tile
    cnt_ref: (1, 1)  non-zero *pair* count for this tile (f32 exact for
             counts < 2^24; checked against the oracle)
    """
    tau_a = ta_ref[0, 0]
    tau_w = tw_ref[0, 0]
    # Clip modules: zero anything with magnitude strictly below the
    # threshold (values equal to the threshold survive, matching ref.py).
    x = x_ref[...]
    w = w_ref[...]
    xc = jnp.where(jnp.abs(x) >= tau_a, x, 0.0)
    wc = jnp.where(jnp.abs(w) >= tau_w, w, 0.0)
    # MAC array consumes the exactly-sparse tiles (MXU on real hardware).
    o_ref[...] = jnp.dot(xc, wc, preferred_element_type=jnp.float32)
    # Zero-filter counter: a pair (m, k, n) is dispatched to a MAC only if
    # both operands are non-zero.  #pairs = sum_k nnz_col(x, k) * nnz_row(w, k)
    # — O(K*(bm+N)) instead of a boolean matmul.
    xnz = jnp.sum((xc != 0.0).astype(jnp.float32), axis=0)  # (K,)
    wnz = jnp.sum((wc != 0.0).astype(jnp.float32), axis=1)  # (K,)
    cnt_ref[0, 0] = jnp.dot(xnz, wnz)


@functools.partial(jax.jit, static_argnames=("block_m",))
def spe_matmul(x, w, tau_w, tau_a, *, block_m=DEFAULT_BLOCK_M):
    """Thresholded sparse matmul with exact non-zero-pair accounting.

    Args:
      x: (M, K) f32 activations (im2col patches for a conv layer).
      w: (K, N) f32 weights.
      tau_w, tau_a: scalar f32 clip thresholds (runtime values).
      block_m: tile rows per grid step (static).

    Returns:
      out:       (M, N) f32 — clip(x) @ clip(w).
      nnz_pairs: () f32 — number of (m, k, n) multiply pairs where both
                 operands are non-zero after clipping.  The dense pair count
                 is M * K * N; the pair density nnz_pairs / (M*K*N) is the
                 (1 - S̄) of the paper's Eq. 1.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm = min(block_m, m)
    if m % bm != 0:
        pad = bm - m % bm
        # Zero rows are exactly-sparse: they contribute neither output nor
        # counted pairs, so padding is free in both numerics and statistics.
        x = jnp.pad(x, ((0, pad), (0, 0)))
        m_p = m + pad
    else:
        m_p = m
    gm = m_p // bm
    tw = jnp.asarray(tau_w, jnp.float32).reshape(1, 1)
    ta = jnp.asarray(tau_a, jnp.float32).reshape(1, 1)

    out, cnt = pl.pallas_call(
        _spe_kernel,
        grid=(gm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_p, n), jnp.float32),
            jax.ShapeDtypeStruct((gm, 1), jnp.float32),
        ],
        interpret=True,
    )(x, w, tw, ta)
    return out[:m], jnp.sum(cnt)


def clip_magnitude(v, tau):
    """The SPE clip module as a standalone op: zero |v| < tau."""
    return jnp.where(jnp.abs(v) >= tau, v, jnp.zeros_like(v))
