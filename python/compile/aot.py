"""AOT compile path: train -> fold -> lower -> emit artifacts.

Emits HLO *text* (NOT `.serialize()`): jax >= 0.5 serialises HloModuleProto
with 64-bit instruction ids which xla_extension 0.5.1 (the version the
`xla` 0.1.6 crate binds) rejects; the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Artifacts (all consumed by the Rust coordinator, never by Python again):

  model.hlo.txt        inference+stats, B=64, thresholds as runtime inputs
  train_step.hlo.txt   one masked-SGD fine-tuning step (extension feature)
  weights.bin          folded + Q8.8-quantised params, f32 LE, meta order
  calib_images.bin     calibration/validation images, f32 LE
  calib_labels.bin     labels, i32 LE
  meta.json            layer table, input order, |w|/|a| quantiles, golden
                       outputs for Rust integration tests
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import common, dataset, model, train

EXPORT_BLOCK_M = 8192  # interpret-mode grid amortisation; see §Perf
QUANTILE_PTS = [i / 20.0 for i in range(21)]


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ------------------------------------------------------- exported graphs


def make_infer_fn(batch):
    """(images, w0, b0, ..., w9, b9, tau_w, tau_a) -> 4-tuple outputs."""

    def fn(images, *rest):
        flat, tw, ta = rest[:-2], rest[-2], rest[-1]
        params = [(flat[2 * i], flat[2 * i + 1]) for i in range(common.NUM_LAYERS)]
        return model.forward(params, images, tw, ta, block_m=EXPORT_BLOCK_M)

    args = [jax.ShapeDtypeStruct((batch, common.IMG_SIZE, common.IMG_SIZE,
                                  common.IMG_CHANNELS), jnp.float32)]
    for spec in common.LAYERS:
        args.append(jax.ShapeDtypeStruct(spec.weight_shape(), jnp.float32))
        args.append(jax.ShapeDtypeStruct((spec.cout,), jnp.float32))
    args += [jax.ShapeDtypeStruct((common.NUM_LAYERS,), jnp.float32)] * 2
    return fn, args


def make_train_step_fn(batch):
    """One masked-SGD step on the folded network (fine-tuning extension).

    Weight clipping inside the forward means pruned weights receive zero
    gradient (d/dw where(|w|>=tau, w, 0) is the keep-mask), i.e. masked
    fine-tuning with the one-shot mask — the paper's future-work item.
    """

    def fn(images, labels, *rest):
        flat, tw, ta, lr = rest[:-3], rest[-3], rest[-2], rest[-1]
        params = [(flat[2 * i], flat[2 * i + 1]) for i in range(common.NUM_LAYERS)]

        def loss_fn(params):
            logits, _, _, _ = model.forward(
                params, images, tw, ta, quantize=False, use_pallas=False
            )
            one_hot = jax.nn.one_hot(labels, common.NUM_CLASSES)
            return -jnp.mean(jnp.sum(one_hot * jax.nn.log_softmax(logits), axis=-1))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        out = []
        for w, b in new:
            out += [w, b]
        return tuple(out) + (loss,)

    args = [
        jax.ShapeDtypeStruct((batch, common.IMG_SIZE, common.IMG_SIZE,
                              common.IMG_CHANNELS), jnp.float32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    ]
    for spec in common.LAYERS:
        args.append(jax.ShapeDtypeStruct(spec.weight_shape(), jnp.float32))
        args.append(jax.ShapeDtypeStruct((spec.cout,), jnp.float32))
    args += [jax.ShapeDtypeStruct((common.NUM_LAYERS,), jnp.float32)] * 2
    args.append(jax.ShapeDtypeStruct((), jnp.float32))
    return fn, args


# ------------------------------------------------------------ statistics


def weight_quantiles(folded):
    """Per-layer |w| quantiles (post-quantisation) for threshold mapping."""
    out = []
    for w, _ in folded:
        a = np.abs(np.asarray(w)).ravel()
        out.append(np.quantile(a, QUANTILE_PTS).tolist())
    return out


def activation_quantiles(folded, images):
    """Per-layer |a| quantiles of each layer's input activation at tau=0."""
    # Instrument via the oracle path (cheap, no pallas) with zero thresholds;
    # collect inputs by re-running forward and capturing pre-conv tensors.
    taus = jnp.zeros((common.NUM_LAYERS,))
    acts = {}

    orig_layer = model._layer

    def capture_layer(idx, x, w, b, tau_w, tau_a, **kw):
        acts[idx] = np.abs(np.asarray(model.fxp_quantize(x))).ravel()
        return orig_layer(idx, x, w, b, tau_w, tau_a, **kw)

    model._layer = capture_layer
    try:
        model.forward(folded, images, taus, taus, use_pallas=False)
    finally:
        model._layer = orig_layer
    return [np.quantile(acts[i], QUANTILE_PTS).tolist()
            for i in range(common.NUM_LAYERS)]


# ----------------------------------------------------------------- main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True, help="path of model.hlo.txt; "
                    "all other artifacts land in its directory")
    ap.add_argument("--epochs", type=int, default=18)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    outdir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(outdir, exist_ok=True)

    # 1. data ------------------------------------------------------------
    (tx, ty), (vx, vy) = dataset.train_val()
    vx.astype("<f4").tofile(os.path.join(outdir, "calib_images.bin"))
    vy.astype("<i4").tofile(os.path.join(outdir, "calib_labels.bin"))

    # 2. train + fold + quantise ------------------------------------------
    params, state, dense_acc = train.train(
        (tx, ty), (vx, vy), epochs=args.epochs, seed=args.seed,
        verbose=not args.quiet,
    )
    print(f"[aot] dense val accuracy: {dense_acc:.4f}")
    folded = train.fold_bn(params, state)
    folded = [(model.fxp_quantize(w), model.fxp_quantize(b)) for w, b in folded]

    # 3. weights.bin + meta ------------------------------------------------
    blobs, layer_meta, off = [], [], 0
    for spec, (w, b) in zip(common.LAYERS, folded):
        wa = np.asarray(w, dtype="<f4")
        ba = np.asarray(b, dtype="<f4")
        layer_meta.append({
            "name": spec.name, "kind": spec.kind, "kernel": spec.kernel,
            "stride": spec.stride, "cin": spec.cin, "cout": spec.cout,
            "in_hw": spec.in_hw, "out_hw": spec.out_hw,
            "patch_k": spec.patch_k(), "macs_per_image": spec.macs_per_image(),
            "weight_shape": list(wa.shape),
            "w_offset": off, "w_size": wa.size,
            "b_offset": off + wa.size, "b_size": ba.size,
        })
        off += wa.size + ba.size
        blobs += [wa, ba]
    np.concatenate([b.ravel() for b in blobs]).tofile(
        os.path.join(outdir, "weights.bin"))

    # 4. golden outputs for Rust integration tests ------------------------
    b = common.EXPORT_BATCH
    imgs = jnp.asarray(vx[:b])
    tau0 = jnp.zeros((common.NUM_LAYERS,))
    tau_ref = jnp.full((common.NUM_LAYERS,), 0.05)
    g_logits0, g_sw0, g_sa0, g_d0 = model.forward(
        folded, imgs, tau0, tau0, block_m=EXPORT_BLOCK_M)
    g_logits1, g_sw1, g_sa1, g_d1 = model.forward(
        folded, imgs, tau_ref, tau_ref, block_m=EXPORT_BLOCK_M)
    golden = {
        "batch": b,
        "tau_ref": 0.05,
        "logits_sum_tau0": float(jnp.sum(g_logits0)),
        "acc_tau0": float(model.accuracy(g_logits0, jnp.asarray(vy[:b]))),
        "s_w_tau_ref": np.asarray(g_sw1).tolist(),
        "s_a_tau_ref": np.asarray(g_sa1).tolist(),
        "pair_density_tau_ref": np.asarray(g_d1).tolist(),
        "pair_density_tau0": np.asarray(g_d0).tolist(),
        "logits_first8_tau_ref": np.asarray(g_logits1[0, :8]).tolist(),
    }

    # 5. lower + emit HLO --------------------------------------------------
    infer_fn, infer_args = make_infer_fn(common.EXPORT_BATCH)
    hlo = to_hlo_text(jax.jit(infer_fn).lower(*infer_args))
    with open(args.out, "w") as f:
        f.write(hlo)
    print(f"[aot] wrote {args.out} ({len(hlo)} chars)")

    ts_fn, ts_args = make_train_step_fn(common.TRAIN_BATCH)
    hlo_ts = to_hlo_text(jax.jit(ts_fn).lower(*ts_args))
    ts_path = os.path.join(outdir, "train_step.hlo.txt")
    with open(ts_path, "w") as f:
        f.write(hlo_ts)
    print(f"[aot] wrote {ts_path} ({len(hlo_ts)} chars)")

    meta = {
        "format_version": 1,
        "model": "calibnet-resnet8",
        "export_batch": common.EXPORT_BATCH,
        "train_batch": common.TRAIN_BATCH,
        "num_layers": common.NUM_LAYERS,
        "num_classes": common.NUM_CLASSES,
        "img_size": common.IMG_SIZE,
        "img_channels": common.IMG_CHANNELS,
        "block_m": EXPORT_BLOCK_M,
        "fxp_scale": common.FXP_SCALE,
        "dense_val_accuracy": float(dense_acc),
        "n_calib": int(vx.shape[0]),
        "quantile_pts": QUANTILE_PTS,
        "weight_abs_quantiles": weight_quantiles(folded),
        "act_abs_quantiles": activation_quantiles(folded, imgs),
        "layers": layer_meta,
        "input_order": "images, then (w_l, b_l) for l in 0..10, tau_w, tau_a",
        "output_order": "logits, s_w, s_a, pair_density",
        "golden": golden,
    }
    with open(os.path.join(outdir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print("[aot] wrote meta.json; done")


if __name__ == "__main__":
    main()
