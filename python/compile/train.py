"""Build-time training of CalibNet (dense, BN) + BN folding.

Runs ONCE inside `make artifacts` (never on the search path).  Trains the
dense network with batchnorm on the synthetic dataset, then folds BN into
conv weights/biases so the exported inference model (model.py) is a pure
conv+bias network — matching standard post-training pruning practice and
the paper's one-shot, no-fine-tuning setting.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import common

BN_EPS = 1e-5
BN_MOMENTUM = 0.9


# ---------------------------------------------------------------- params


def init_params(rng):
    """He-initialised conv weights + BN (gamma, beta) / fc bias."""
    params = {}
    for i, spec in enumerate(common.LAYERS):
        rng, k = jax.random.split(rng)
        shape = spec.weight_shape()
        fan_in = spec.patch_k()
        w = jax.random.normal(k, shape) * jnp.sqrt(2.0 / fan_in)
        if spec.kind == "linear":
            params[f"w{i}"] = w
            params[f"b{i}"] = jnp.zeros((spec.cout,))
        else:
            params[f"w{i}"] = w
            params[f"gamma{i}"] = jnp.ones((spec.cout,))
            params[f"beta{i}"] = jnp.zeros((spec.cout,))
    return params


def init_bn_state():
    state = {}
    for i, spec in enumerate(common.LAYERS):
        if spec.kind == "conv":
            state[f"mean{i}"] = jnp.zeros((spec.cout,))
            state[f"var{i}"] = jnp.ones((spec.cout,))
    return state


# --------------------------------------------------------------- forward


def _conv(x, w, spec):
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(spec.stride, spec.stride),
        padding=[(spec.pad, spec.pad), (spec.pad, spec.pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _bn(i, y, params, state, train):
    if train:
        mean = jnp.mean(y, axis=(0, 1, 2))
        var = jnp.var(y, axis=(0, 1, 2))
        new = (
            BN_MOMENTUM * state[f"mean{i}"] + (1 - BN_MOMENTUM) * mean,
            BN_MOMENTUM * state[f"var{i}"] + (1 - BN_MOMENTUM) * var,
        )
    else:
        mean, var = state[f"mean{i}"], state[f"var{i}"]
        new = (mean, var)
    yhat = (y - mean) * jax.lax.rsqrt(var + BN_EPS)
    return yhat * params[f"gamma{i}"] + params[f"beta{i}"], new


def dense_forward(params, state, images, train=False):
    """Dense (unpruned) forward, BN included. Returns (logits, new_state)."""
    new_state = dict(state)

    def cbn(i, x):
        y = _conv(x, params[f"w{i}"], common.LAYERS[i])
        y, (m, v) = _bn(i, y, params, state, train)
        new_state[f"mean{i}"], new_state[f"var{i}"] = m, v
        return y

    x = jax.nn.relu(cbn(0, images))
    h = jax.nn.relu(cbn(1, x))
    x = jax.nn.relu(cbn(2, h) + x)
    h = jax.nn.relu(cbn(3, x))
    x = jax.nn.relu(cbn(4, h) + cbn(5, x))
    h = jax.nn.relu(cbn(6, x))
    x = jax.nn.relu(cbn(7, h) + cbn(8, x))
    x = jnp.mean(x, axis=(1, 2))
    logits = x @ params["w9"] + params["b9"]
    return logits, new_state


# -------------------------------------------------------------- training


def _loss(params, state, images, labels, wd):
    logits, new_state = dense_forward(params, state, images, train=True)
    one_hot = jax.nn.one_hot(labels, common.NUM_CLASSES)
    ce = -jnp.mean(jnp.sum(one_hot * jax.nn.log_softmax(logits), axis=-1))
    l2 = sum(jnp.sum(v * v) for k, v in params.items() if k.startswith("w"))
    return ce + wd * l2, new_state


@functools.partial(jax.jit, static_argnames=("wd",))
def _train_step(params, state, mom, images, labels, lr, wd):
    (loss, new_state), grads = jax.value_and_grad(_loss, has_aux=True)(
        params, state, images, labels, wd
    )
    new_mom = jax.tree.map(lambda m, g: 0.9 * m + g, mom, grads)
    new_params = jax.tree.map(lambda p, m: p - lr * m, params, new_mom)
    return new_params, new_state, new_mom, loss


@jax.jit
def _eval_batch(params, state, images, labels):
    logits, _ = dense_forward(params, state, images, train=False)
    return jnp.sum((jnp.argmax(logits, -1) == labels).astype(jnp.float32))


def evaluate(params, state, images, labels, batch=256):
    n = images.shape[0]
    correct = 0.0
    for i in range(0, n - n % batch, batch):
        correct += float(
            _eval_batch(params, state, images[i : i + batch], labels[i : i + batch])
        )
    return correct / (n - n % batch)


def train(train_set, val_set, *, epochs=18, batch=128, base_lr=0.1, wd=1e-4,
          seed=0, verbose=True):
    """Train CalibNet; returns (params, bn_state, val_accuracy)."""
    tx, ty = train_set
    params = init_params(jax.random.PRNGKey(seed))
    state = init_bn_state()
    mom = jax.tree.map(jnp.zeros_like, params)
    n = tx.shape[0]
    steps_per_epoch = n // batch
    total_steps = epochs * steps_per_epoch
    rng = np.random.default_rng(seed)
    step = 0
    for ep in range(epochs):
        perm = rng.permutation(n)
        for i in range(steps_per_epoch):
            idx = perm[i * batch : (i + 1) * batch]
            xb = jnp.asarray(tx[idx])
            # light augmentation: random horizontal flip per batch
            if rng.random() < 0.5:
                xb = xb[:, :, ::-1, :]
            lr = base_lr * 0.5 * (1 + np.cos(np.pi * step / total_steps))
            params, state, mom, loss = _train_step(
                params, state, mom, xb, jnp.asarray(ty[idx]), lr, wd
            )
            step += 1
        if verbose:
            acc = evaluate(params, state, *map(jnp.asarray, val_set))
            print(f"[train] epoch {ep + 1}/{epochs} loss={float(loss):.3f} val_acc={acc:.4f}")
    val_acc = evaluate(params, state, *map(jnp.asarray, val_set))
    return params, state, val_acc


# --------------------------------------------------------------- folding


def fold_bn(params, state):
    """Fold BN into conv weights/biases -> [(w, b)] in model.forward order."""
    folded = []
    for i, spec in enumerate(common.LAYERS):
        w = params[f"w{i}"]
        if spec.kind == "linear":
            folded.append((w, params[f"b{i}"]))
            continue
        scale = params[f"gamma{i}"] * jax.lax.rsqrt(state[f"var{i}"] + BN_EPS)
        w_f = w * scale  # broadcast over cout (last axis of HWIO)
        b_f = params[f"beta{i}"] - state[f"mean{i}"] * scale
        folded.append((w_f, b_f))
    return folded
