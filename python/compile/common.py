"""Shared architecture/config definitions for the calibration CNN.

The calibration network ("CalibNet") is the small, *really executed*
network of the HASS reproduction: it is trained at `make artifacts` time,
AOT-lowered (with the Pallas SPE kernel inside) to HLO text, and executed
by the Rust coordinator via PJRT on every TPE iteration to measure
accuracy and per-layer weight/activation sparsity under candidate
thresholds.  See DESIGN.md §1.1 for how its measured statistics transfer
to the five target network geometries.

Topology — a compact pre-folded (conv + bias) residual net for 32x32x3
inputs, 10 classes:

  idx  name        kind     k  s  cin  cout  notes
  0    stem        conv3x3  3  1  3    16
  1    b1.conv1    conv3x3  3  1  16   16    block 1 (identity shortcut)
  2    b1.conv2    conv3x3  3  1  16   16
  3    b2.conv1    conv3x3  3  2  16   32    block 2 (projection shortcut)
  4    b2.conv2    conv3x3  3  1  32   32
  5    b2.down     conv1x1  1  2  16   32
  6    b3.conv1    conv3x3  3  2  32   64    block 3 (projection shortcut)
  7    b3.conv2    conv3x3  3  1  64   64
  8    b3.down     conv1x1  1  2  32   64
  9    fc          linear   -  -  64   10    after global average pool

All 10 layers are prunable; thresholds tau_w[10], tau_a[10] are runtime
inputs of the AOT artifact.
"""

import dataclasses

IMG_SIZE = 32
IMG_CHANNELS = 3
NUM_CLASSES = 10
NUM_LAYERS = 10
EXPORT_BATCH = 64  # batch size of the inference artifact
TRAIN_BATCH = 128  # batch size of the train-step artifact

# Fixed-point format used by the hardware model (paper: 16-bit fixed).
# Q8.8: 1 sign + 7 integer + 8 fractional bits.
FXP_SCALE = 256.0
FXP_MAX = 127.0 + 255.0 / 256.0
FXP_MIN = -128.0


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """Geometry of one prunable layer of CalibNet."""

    name: str
    kind: str  # "conv" | "linear"
    kernel: int
    stride: int
    cin: int
    cout: int
    in_hw: int  # input spatial size (conv only; 1 for linear)

    @property
    def out_hw(self):
        return self.in_hw // self.stride if self.kind == "conv" else 1

    @property
    def pad(self):
        return (self.kernel - 1) // 2

    def weight_shape(self):
        if self.kind == "linear":
            return (self.cin, self.cout)
        return (self.kernel, self.kernel, self.cin, self.cout)

    def patch_k(self):
        """K dimension of the im2col'd matmul."""
        if self.kind == "linear":
            return self.cin
        return self.kernel * self.kernel * self.cin

    def macs_per_image(self):
        """Dense operation count C_l per image (including zeros)."""
        if self.kind == "linear":
            return self.cin * self.cout
        return self.out_hw * self.out_hw * self.patch_k() * self.cout


LAYERS = [
    ConvSpec("stem", "conv", 3, 1, IMG_CHANNELS, 16, 32),
    ConvSpec("b1.conv1", "conv", 3, 1, 16, 16, 32),
    ConvSpec("b1.conv2", "conv", 3, 1, 16, 16, 32),
    ConvSpec("b2.conv1", "conv", 3, 2, 16, 32, 32),
    ConvSpec("b2.conv2", "conv", 3, 1, 32, 32, 16),
    ConvSpec("b2.down", "conv", 1, 2, 16, 32, 32),
    ConvSpec("b3.conv1", "conv", 3, 2, 32, 64, 16),
    ConvSpec("b3.conv2", "conv", 3, 1, 64, 64, 8),
    ConvSpec("b3.down", "conv", 1, 2, 32, 64, 16),
    ConvSpec("fc", "linear", 0, 0, 64, NUM_CLASSES, 1),
]

assert len(LAYERS) == NUM_LAYERS


def param_sizes():
    """(weights, bias) element counts per layer, artifact input order."""
    out = []
    for spec in LAYERS:
        w = 1
        for d in spec.weight_shape():
            w *= d
        out.append((w, spec.cout))
    return out


def total_params():
    return sum(w + b for w, b in param_sizes())
