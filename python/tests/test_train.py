"""Training + BN folding correctness (build-time path)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import common, dataset, model, train

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def tiny_run():
    (tx, ty), (vx, vy) = dataset.train_val(n_train=1536, n_val=256)
    params, state, acc = train.train((tx, ty), (vx, vy), epochs=3,
                                     verbose=False)
    return params, state, acc, (vx, vy)


class TestTraining:
    def test_init_shapes(self):
        params = train.init_params(jax.random.PRNGKey(0))
        for i, spec in enumerate(common.LAYERS):
            assert params[f"w{i}"].shape == spec.weight_shape()
            if spec.kind == "conv":
                assert params[f"gamma{i}"].shape == (spec.cout,)

    def test_learns_above_chance(self, tiny_run):
        _, _, acc, _ = tiny_run
        assert acc > 0.3  # 10 classes, chance = 0.1

    def test_dense_forward_shapes(self, tiny_run):
        params, state, _, (vx, _) = tiny_run
        logits, _ = train.dense_forward(params, state, jnp.asarray(vx[:8]))
        assert logits.shape == (8, common.NUM_CLASSES)

    def test_bn_state_updated_in_train_mode(self):
        params = train.init_params(jax.random.PRNGKey(0))
        state = train.init_bn_state()
        x = jnp.asarray(np.random.default_rng(0)
                        .standard_normal((8, 32, 32, 3)).astype(np.float32))
        _, new_state = train.dense_forward(params, state, x, train=True)
        assert not np.allclose(np.asarray(new_state["mean0"]),
                               np.asarray(state["mean0"]))

    def test_bn_state_frozen_in_eval_mode(self):
        params = train.init_params(jax.random.PRNGKey(0))
        state = train.init_bn_state()
        x = jnp.zeros((4, 32, 32, 3))
        _, new_state = train.dense_forward(params, state, x, train=False)
        np.testing.assert_array_equal(np.asarray(new_state["mean0"]),
                                      np.asarray(state["mean0"]))


class TestFolding:
    def test_fold_exactness(self, tiny_run):
        """Folded conv+bias forward == dense BN forward in eval mode."""
        params, state, _, (vx, _) = tiny_run
        folded = train.fold_bn(params, state)
        imgs = jnp.asarray(vx[:16])
        want, _ = train.dense_forward(params, state, imgs, train=False)
        got, *_ = model.forward(folded, imgs,
                                jnp.zeros(10), jnp.zeros(10),
                                quantize=False, use_pallas=False)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_fold_output_structure(self, tiny_run):
        params, state, _, _ = tiny_run
        folded = train.fold_bn(params, state)
        assert len(folded) == common.NUM_LAYERS
        for (w, b), spec in zip(folded, common.LAYERS):
            assert w.shape == spec.weight_shape()
            assert b.shape == (spec.cout,)

    def test_quantised_fold_still_accurate(self, tiny_run):
        """Q8.8 quantisation must not destroy the trained network."""
        params, state, acc, (vx, vy) = tiny_run
        folded = [(model.fxp_quantize(w), model.fxp_quantize(b))
                  for w, b in train.fold_bn(params, state)]
        logits, *_ = model.forward(folded, jnp.asarray(vx[:256]),
                                   jnp.zeros(10), jnp.zeros(10),
                                   use_pallas=False)
        qacc = float(model.accuracy(logits, jnp.asarray(vy[:256])))
        assert qacc > acc - 0.1
