"""L2 correctness: CalibNet forward — shapes, im2col, stats, quantisation."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import common, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand_params(seed=0, scale=0.1):
    rng = np.random.default_rng(seed)
    params = []
    for spec in common.LAYERS:
        w = rng.standard_normal(spec.weight_shape()).astype(np.float32) * scale
        b = rng.standard_normal((spec.cout,)).astype(np.float32) * 0.01
        params.append((jnp.asarray(w), jnp.asarray(b)))
    return params


@pytest.fixture(scope="module")
def params():
    return rand_params()


@pytest.fixture(scope="module")
def images():
    rng = np.random.default_rng(42)
    return jnp.asarray(rng.standard_normal((4, 32, 32, 3)).astype(np.float32))


ZERO = jnp.zeros((common.NUM_LAYERS,))


# ----------------------------------------------------------------- im2col


class TestIm2col:
    @pytest.mark.parametrize("idx", range(9))
    def test_matches_lax_conv(self, idx):
        """im2col @ reshaped-w must equal lax.conv for every conv layer."""
        spec = common.LAYERS[idx]
        rng = np.random.default_rng(idx)
        x = jnp.asarray(rng.standard_normal(
            (2, spec.in_hw, spec.in_hw, spec.cin)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal(
            spec.weight_shape()).astype(np.float32))
        patches = model.im2col(x, spec)
        got = (patches @ w.reshape(spec.patch_k(), spec.cout)).reshape(
            2, spec.out_hw, spec.out_hw, spec.cout)
        want = jax.lax.conv_general_dilated(
            x, w, (spec.stride, spec.stride),
            [(spec.pad, spec.pad)] * 2,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_patch_shape(self):
        spec = common.LAYERS[0]
        x = jnp.zeros((3, 32, 32, 3))
        assert model.im2col(x, spec).shape == (3 * 32 * 32, 27)

    def test_strided_patch_shape(self):
        spec = common.LAYERS[3]  # stride 2, 16 -> 32
        x = jnp.zeros((2, 32, 32, 16))
        assert model.im2col(x, spec).shape == (2 * 16 * 16, 144)


# ---------------------------------------------------------------- forward


class TestForward:
    def test_output_shapes(self, params, images):
        logits, s_w, s_a, dens = model.forward(params, images, ZERO, ZERO,
                                               use_pallas=False)
        assert logits.shape == (4, common.NUM_CLASSES)
        assert s_w.shape == s_a.shape == dens.shape == (common.NUM_LAYERS,)

    def test_pallas_and_oracle_paths_agree(self, params, images):
        tw = jnp.full((10,), 0.03)
        ta = jnp.full((10,), 0.08)
        a = model.forward(params, images, tw, ta, use_pallas=True)
        b = model.forward(params, images, tw, ta, use_pallas=False)
        np.testing.assert_allclose(a[0], b[0], rtol=1e-4, atol=1e-4)
        for i in range(1, 4):
            np.testing.assert_allclose(a[i], b[i], rtol=1e-5, atol=1e-6)

    def test_stats_in_unit_range(self, params, images):
        tw = jnp.full((10,), 0.05)
        ta = jnp.full((10,), 0.05)
        _, s_w, s_a, dens = model.forward(params, images, tw, ta,
                                          use_pallas=False)
        for v in (s_w, s_a, dens):
            assert np.all(np.asarray(v) >= 0.0) and np.all(np.asarray(v) <= 1.0)

    def test_zero_thresholds_give_zero_weight_sparsity(self, params, images):
        _, s_w, _, _ = model.forward(params, images, ZERO, ZERO,
                                     use_pallas=False)
        # random normal weights have no exact zeros
        np.testing.assert_array_equal(np.asarray(s_w), 0.0)

    def test_sparsity_monotone_in_threshold(self, params, images):
        outs = []
        for t in [0.0, 0.05, 0.2]:
            _, s_w, s_a, dens = model.forward(
                params, images, jnp.full((10,), t), jnp.full((10,), t),
                use_pallas=False)
            outs.append((np.asarray(s_w), np.asarray(s_a), np.asarray(dens)))
        for a, b in zip(outs, outs[1:]):
            assert np.all(b[0] >= a[0])  # S_w non-decreasing
            assert np.all(b[1] >= a[1])  # S_a non-decreasing
            assert np.all(b[2] <= a[2] + 1e-6)  # density non-increasing

    def test_huge_threshold_kills_network(self, params, images):
        t = jnp.full((10,), 1e9)
        logits, s_w, s_a, dens = model.forward(params, images, t, t,
                                               use_pallas=False)
        np.testing.assert_array_equal(np.asarray(s_w), 1.0)
        np.testing.assert_array_equal(np.asarray(dens), 0.0)

    def test_per_layer_threshold_is_local(self, params, images):
        """Raising only layer 7's tau_w must not change earlier stats."""
        tw = np.zeros(10, np.float32)
        base = model.forward(params, images, jnp.asarray(tw), ZERO,
                             use_pallas=False)
        tw[7] = 0.5
        mod = model.forward(params, images, jnp.asarray(tw), ZERO,
                            use_pallas=False)
        np.testing.assert_array_equal(np.asarray(base[1][:7]),
                                      np.asarray(mod[1][:7]))
        assert float(mod[1][7]) > float(base[1][7])

    def test_batch_size_one(self, params):
        img = jnp.zeros((1, 32, 32, 3))
        logits, *_ = model.forward(params, img, ZERO, ZERO, use_pallas=False)
        assert logits.shape == (1, common.NUM_CLASSES)


# ----------------------------------------------------------- quantisation


class TestQuantisation:
    def test_fxp_idempotent(self):
        rng = np.random.default_rng(1)
        v = jnp.asarray(rng.standard_normal(1000).astype(np.float32))
        q = model.fxp_quantize(v)
        np.testing.assert_array_equal(np.asarray(model.fxp_quantize(q)),
                                      np.asarray(q))

    def test_fxp_grid(self):
        v = jnp.asarray([0.1, -0.30078125, 200.0, -200.0], dtype=jnp.float32)
        q = np.asarray(model.fxp_quantize(v))
        assert q[0] == pytest.approx(np.round(0.1 * 256) / 256)
        assert q[1] == -0.30078125  # already on grid
        assert q[2] == common.FXP_MAX and q[3] == common.FXP_MIN

    def test_quantize_changes_logits_but_little(self, params, images):
        a = model.forward(params, images, ZERO, ZERO, quantize=True,
                          use_pallas=False)
        b = model.forward(params, images, ZERO, ZERO, quantize=False,
                          use_pallas=False)
        diff = np.abs(np.asarray(a[0]) - np.asarray(b[0])).max()
        assert 0.0 < diff < 0.5


# -------------------------------------------------------------- hypothesis


@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(st.integers(0, 10_000), st.floats(0, 0.3), st.floats(0, 0.3))
def test_forward_finite_and_consistent(seed, tw, ta):
    params = rand_params(seed % 7)
    rng = np.random.default_rng(seed)
    imgs = jnp.asarray(rng.standard_normal((2, 32, 32, 3)).astype(np.float32))
    tws = jnp.full((10,), tw)
    tas = jnp.full((10,), ta)
    logits, s_w, s_a, dens = model.forward(params, imgs, tws, tas,
                                           use_pallas=False)
    assert np.all(np.isfinite(np.asarray(logits)))
    assert np.all((np.asarray(dens) >= 0) & (np.asarray(dens) <= 1))


# ------------------------------------------------------------ common spec


class TestCommonSpec:
    def test_layer_count(self):
        assert len(common.LAYERS) == 10

    def test_macs_per_image_stem(self):
        # 32*32 outputs * 27 patch * 16 filters
        assert common.LAYERS[0].macs_per_image() == 32 * 32 * 27 * 16

    def test_macs_per_image_fc(self):
        assert common.LAYERS[9].macs_per_image() == 64 * 10

    def test_total_params_reasonable(self):
        assert 70_000 < common.total_params() < 90_000

    def test_out_hw_strides(self):
        assert [s.out_hw for s in common.LAYERS[:9]] == [32, 32, 32, 16, 16,
                                                         16, 8, 8, 8]

    def test_param_sizes_match_shapes(self):
        for (w, b), spec in zip(common.param_sizes(), common.LAYERS):
            assert b == spec.cout
            prod = 1
            for d in spec.weight_shape():
                prod *= d
            assert w == prod
