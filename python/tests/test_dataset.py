"""Synthetic dataset: determinism, shapes, learnable structure."""

import numpy as np

from compile import common, dataset


class TestDataset:
    def test_shapes_and_dtypes(self):
        x, y = dataset.make_dataset(64, seed=1)
        assert x.shape == (64, 32, 32, 3) and x.dtype == np.float32
        assert y.shape == (64,) and y.dtype == np.int32

    def test_deterministic(self):
        a = dataset.make_dataset(16, seed=7)
        b = dataset.make_dataset(16, seed=7)
        np.testing.assert_array_equal(a[0], b[0])
        np.testing.assert_array_equal(a[1], b[1])

    def test_seed_changes_data(self):
        a = dataset.make_dataset(16, seed=7)
        b = dataset.make_dataset(16, seed=8)
        assert not np.array_equal(a[0], b[0])

    def test_labels_cover_classes(self):
        _, y = dataset.make_dataset(500, seed=2)
        assert set(np.unique(y)) == set(range(common.NUM_CLASSES))

    def test_normalised(self):
        x, _ = dataset.make_dataset(256, seed=3)
        assert abs(float(x.mean())) < 0.05
        assert 0.9 < float(x.std()) < 1.1

    def test_classes_statistically_distinct(self):
        """Per-class mean images must differ — the classes carry signal."""
        x, y = dataset.make_dataset(800, seed=4)
        means = np.stack([x[y == c].mean(axis=0) for c in range(10)])
        dists = []
        for i in range(10):
            for j in range(i + 1, 10):
                dists.append(np.abs(means[i] - means[j]).mean())
        assert min(dists) > 0.01

    def test_train_val_split_disjoint_rng(self):
        (tx, _), (vx, _) = dataset.train_val(n_train=32, n_val=32)
        assert not np.array_equal(tx[:32], vx[:32])
