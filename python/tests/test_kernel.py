"""L1 correctness: Pallas SPE kernel vs pure-jnp oracle.

Hypothesis sweeps shapes/thresholds/distributions; assert_allclose against
ref.py.  This is the core numerical signal of the whole stack — the AOT
artifact embeds exactly this kernel.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref, spe

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, shape, scale=1.0, sparsify=0.0):
    v = rng.standard_normal(shape).astype(np.float32) * scale
    if sparsify > 0:
        v[rng.random(shape) < sparsify] = 0.0
    return jnp.asarray(v)


# ------------------------------------------------------------ exact cases


class TestSpeMatmulBasics:
    def test_zero_thresholds_is_dense_matmul(self):
        rng = np.random.default_rng(0)
        x, w = _rand(rng, (64, 27)), _rand(rng, (27, 16))
        out, nnz = spe.spe_matmul(x, w, 0.0, 0.0)
        np.testing.assert_allclose(out, x @ w, rtol=1e-5, atol=1e-5)
        assert float(nnz) == 64 * 27 * 16

    def test_infinite_threshold_zeroes_everything(self):
        rng = np.random.default_rng(1)
        x, w = _rand(rng, (32, 8)), _rand(rng, (8, 4))
        out, nnz = spe.spe_matmul(x, w, 1e9, 1e9)
        np.testing.assert_array_equal(np.asarray(out), 0.0)
        assert float(nnz) == 0.0

    def test_weight_only_clipping(self):
        rng = np.random.default_rng(2)
        x, w = _rand(rng, (16, 8)), _rand(rng, (8, 4))
        out, _ = spe.spe_matmul(x, w, 0.5, 0.0)
        wc = np.where(np.abs(np.asarray(w)) >= 0.5, np.asarray(w), 0.0)
        np.testing.assert_allclose(out, np.asarray(x) @ wc, rtol=1e-5, atol=1e-5)

    def test_activation_only_clipping(self):
        rng = np.random.default_rng(3)
        x, w = _rand(rng, (16, 8)), _rand(rng, (8, 4))
        out, _ = spe.spe_matmul(x, w, 0.0, 0.5)
        xc = np.where(np.abs(np.asarray(x)) >= 0.5, np.asarray(x), 0.0)
        np.testing.assert_allclose(out, xc @ np.asarray(w), rtol=1e-5, atol=1e-5)

    def test_threshold_boundary_value_survives(self):
        # |v| == tau must be kept (>= semantics, matching the oracle)
        x = jnp.asarray([[0.5, -0.5, 0.49]], dtype=jnp.float32)
        w = jnp.ones((3, 1), dtype=jnp.float32)
        out, nnz = spe.spe_matmul(x, w, 0.0, 0.5)
        assert float(out[0, 0]) == 0.0  # 0.5 - 0.5 + 0
        assert float(nnz) == 2.0

    def test_pair_count_hand_computed(self):
        # x row [1, 0], w = [[1, 1], [1, 1]] -> pairs via k=0 only: 1*2 = 2
        x = jnp.asarray([[1.0, 0.0]])
        w = jnp.ones((2, 2), dtype=jnp.float32)
        _, nnz = spe.spe_matmul(x, w, 0.0, 0.0)
        assert float(nnz) == 2.0

    def test_padding_rows_not_counted(self):
        # M=3 with block_m=2 pads one zero row; count must ignore it
        rng = np.random.default_rng(4)
        x, w = _rand(rng, (3, 5)), _rand(rng, (5, 4))
        out, nnz = spe.spe_matmul(x, w, 0.0, 0.0, block_m=2)
        _, nnz_ref = ref.spe_matmul_ref(x, w, 0.0, 0.0)
        assert float(nnz) == float(nnz_ref)
        np.testing.assert_allclose(out, np.asarray(x) @ np.asarray(w),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("block_m", [1, 2, 7, 16, 64, 1024])
    def test_block_size_invariance(self, block_m):
        rng = np.random.default_rng(5)
        x, w = _rand(rng, (33, 12), sparsify=0.4), _rand(rng, (12, 6), sparsify=0.4)
        out, nnz = spe.spe_matmul(x, w, 0.3, 0.2, block_m=block_m)
        out_r, nnz_r = ref.spe_matmul_ref(x, w, 0.3, 0.2)
        np.testing.assert_allclose(out, out_r, rtol=1e-5, atol=1e-5)
        assert float(nnz) == float(nnz_r)


# ------------------------------------------------------- hypothesis sweep


@st.composite
def matmul_case(draw):
    m = draw(st.integers(1, 96))
    k = draw(st.integers(1, 48))
    n = draw(st.integers(1, 24))
    seed = draw(st.integers(0, 2**31 - 1))
    tau_w = draw(st.floats(0.0, 2.0))
    tau_a = draw(st.floats(0.0, 2.0))
    scale = draw(st.sampled_from([0.1, 1.0, 10.0]))
    sparsify = draw(st.sampled_from([0.0, 0.3, 0.8]))
    block_m = draw(st.sampled_from([8, 32, 128]))
    return m, k, n, seed, tau_w, tau_a, scale, sparsify, block_m


@hypothesis.settings(max_examples=40, deadline=None)
@hypothesis.given(matmul_case())
def test_kernel_matches_oracle(case):
    m, k, n, seed, tau_w, tau_a, scale, sparsify, block_m = case
    rng = np.random.default_rng(seed)
    x = _rand(rng, (m, k), scale=scale, sparsify=sparsify)
    w = _rand(rng, (k, n), scale=scale, sparsify=sparsify)
    out, nnz = spe.spe_matmul(x, w, tau_w, tau_a, block_m=block_m)
    out_r, nnz_r = ref.spe_matmul_ref(x, w, tau_w, tau_a)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r),
                               rtol=1e-4, atol=1e-4 * scale * scale * k)
    assert float(nnz) == float(nnz_r)


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(st.integers(0, 2**31 - 1), st.floats(0.0, 1.5),
                  st.floats(0.0, 1.5))
def test_pair_density_bounds_and_monotonicity(seed, t1, t2):
    """Pair count is monotone non-increasing in either threshold."""
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, (24, 16)), _rand(rng, (16, 8))
    lo, hi = sorted([t1, t2])
    _, n_lo = spe.spe_matmul(x, w, lo, lo)
    _, n_hi = spe.spe_matmul(x, w, hi, hi)
    total = 24 * 16 * 8
    assert 0.0 <= float(n_hi) <= float(n_lo) <= total


# ---------------------------------------------------------------- dtypes


class TestDtypes:
    def test_bfloat16_inputs_upcast(self):
        rng = np.random.default_rng(7)
        x = _rand(rng, (16, 8)).astype(jnp.bfloat16).astype(jnp.float32)
        w = _rand(rng, (8, 4)).astype(jnp.bfloat16).astype(jnp.float32)
        out, nnz = spe.spe_matmul(x, w, 0.1, 0.1)
        out_r, nnz_r = ref.spe_matmul_ref(x, w, 0.1, 0.1)
        np.testing.assert_allclose(out, out_r, rtol=1e-4, atol=1e-4)
        assert float(nnz) == float(nnz_r)

    def test_fixed_point_grid_values(self):
        # Q8.8-quantized values: counts must be exact, outputs exact-ish
        rng = np.random.default_rng(8)
        x = jnp.round(_rand(rng, (32, 16)) * 256) / 256
        w = jnp.round(_rand(rng, (16, 8)) * 256) / 256
        tau = 10 / 256.0
        out, nnz = spe.spe_matmul(x, w, tau, tau)
        out_r, nnz_r = ref.spe_matmul_ref(x, w, tau, tau)
        np.testing.assert_allclose(out, out_r, rtol=1e-5, atol=1e-6)
        assert float(nnz) == float(nnz_r)


def test_clip_magnitude_matches_ref():
    rng = np.random.default_rng(9)
    v = _rand(rng, (100,))
    np.testing.assert_array_equal(
        np.asarray(spe.clip_magnitude(v, 0.7)),
        np.asarray(ref.clip_magnitude(v, 0.7)),
    )
