"""AOT path: lowering smoke + artifact invariants (fast, no full training)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, common

jax.config.update("jax_platform_name", "cpu")

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestLowering:
    def test_infer_fn_lowers_to_hlo_text(self):
        fn, args = aot.make_infer_fn(4)
        text = aot.to_hlo_text(jax.jit(fn).lower(*args))
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_infer_arg_count(self):
        _, args = aot.make_infer_fn(4)
        # images + 10 * (w, b) + tau_w + tau_a
        assert len(args) == 1 + 2 * common.NUM_LAYERS + 2

    def test_train_step_lowers(self):
        fn, args = aot.make_train_step_fn(4)
        text = aot.to_hlo_text(jax.jit(fn).lower(*args))
        assert text.startswith("HloModule")

    def test_train_step_arg_count(self):
        _, args = aot.make_train_step_fn(4)
        # images + labels + 10 * (w, b) + tau_w + tau_a + lr
        assert len(args) == 2 + 2 * common.NUM_LAYERS + 3


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACT_DIR, "meta.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestArtifacts:
    """Invariants of the real emitted artifacts (post `make artifacts`)."""

    @pytest.fixture(scope="class")
    def meta(self):
        with open(os.path.join(ARTIFACT_DIR, "meta.json")) as f:
            return json.load(f)

    def test_meta_layer_table(self, meta):
        assert meta["num_layers"] == common.NUM_LAYERS
        for lm, spec in zip(meta["layers"], common.LAYERS):
            assert lm["name"] == spec.name
            assert lm["macs_per_image"] == spec.macs_per_image()

    def test_weights_bin_size(self, meta):
        total = sum(lm["w_size"] + lm["b_size"] for lm in meta["layers"])
        sz = os.path.getsize(os.path.join(ARTIFACT_DIR, "weights.bin"))
        assert sz == total * 4

    def test_calib_set_sizes(self, meta):
        n = meta["n_calib"]
        img = os.path.getsize(os.path.join(ARTIFACT_DIR, "calib_images.bin"))
        lab = os.path.getsize(os.path.join(ARTIFACT_DIR, "calib_labels.bin"))
        assert img == n * 32 * 32 * 3 * 4
        assert lab == n * 4

    def test_dense_accuracy_recorded(self, meta):
        assert meta["dense_val_accuracy"] > 0.7

    def test_quantiles_monotone(self, meta):
        for q in meta["weight_abs_quantiles"] + meta["act_abs_quantiles"]:
            assert len(q) == 21
            assert all(b >= a - 1e-9 for a, b in zip(q, q[1:]))

    def test_golden_block_present(self, meta):
        g = meta["golden"]
        assert g["batch"] == common.EXPORT_BATCH
        assert len(g["s_w_tau_ref"]) == common.NUM_LAYERS
        assert 0.0 <= g["acc_tau0"] <= 1.0

    def test_hlo_artifacts_exist_and_parse_header(self):
        for name in ("model.hlo.txt", "train_step.hlo.txt"):
            p = os.path.join(ARTIFACT_DIR, name)
            with open(p) as f:
                head = f.read(64)
            assert head.startswith("HloModule")

    def test_golden_density_tau0_near_activation_density(self, meta):
        """At tau=0 pair density reflects natural ReLU sparsity: < 1."""
        d = meta["golden"]["pair_density_tau0"]
        assert all(0.0 < x <= 1.0 for x in d)
        # post-ReLU layers must show natural zeros
        assert min(d[1:9]) < 0.999
