//! Quickstart: the four core objects of the HASS library in ~80 lines.
//!
//! 1. a [`Network`] geometry (here: torchvision ResNet-18),
//! 2. its per-layer sparsity operating points,
//! 3. the DSE that turns both into an accelerator design,
//! 4. the batched search engine that explores sparsity and hardware
//!    together (Eq. 6), evaluating each TPE generation in parallel.
//!
//! Run: `cargo run --release --example quickstart`

use hass::arch::networks;
use hass::coordinator::{SearchConfig, SurrogateEvaluator};
use hass::dse::{explore, DseConfig};
use hass::engine::{Engine, EngineConfig};
use hass::hardware::device::DeviceBudget;
use hass::hardware::resources::ResourceModel;
use hass::pruning::{self, PruningPlan};
use hass::sparsity::synthesize;

fn main() {
    // -- 1. a workload geometry --------------------------------------
    let net = networks::resnet18();
    println!(
        "{}: {} layers ({} compute), {:.2} GMACs, {:.1}M params",
        net.name,
        net.layers.len(),
        net.compute_layers().len(),
        net.total_macs() as f64 / 1e9,
        net.total_weights() as f64 / 1e6
    );

    // -- 2. sparsity: one-shot magnitude pruning at 60%/natural -------
    let sparsity = synthesize(&net, /*seed=*/ 1);
    let n = sparsity.layers.len();
    let mut x = vec![0.0; 2 * n];
    for i in 0..n {
        x[2 * i] = 0.6 / pruning::MAX_SPARSITY; // weight-sparsity target 0.6
        x[2 * i + 1] = 0.0; // activations: natural zeros only
    }
    let plan = PruningPlan::from_unit_point(&x, &sparsity);
    let points = plan.points(&sparsity);
    let m = pruning::metrics(&net, &points);
    println!(
        "pruned: avg sparsity {:.3}, operation density {:.3}, weight sparsity {:.3}",
        m.avg_sparsity, m.op_density, m.weight_sparsity
    );

    // -- 3. hardware: DSE onto an Alveo U250 --------------------------
    let dev = DeviceBudget::u250();
    let rm = ResourceModel::default();
    let design = explore(&net, &points, &rm, &dev, &DseConfig::default());
    println!(
        "design: {:.0} img/s | {} DSP | {} kLUT | {} BRAM18k | {:.3e} img/cycle/DSP",
        design.images_per_sec(&dev),
        design.resources.dsp,
        design.resources.lut / 1000,
        design.resources.bram18k,
        design.efficiency()
    );

    // dense reference for the speedup headline (Fig. 6's view)
    let dense_pts = vec![hass::sparsity::SparsityPoint::DENSE; n];
    let dense = explore(&net, &dense_pts, &rm, &dev, &DseConfig::default());
    println!(
        "dense reference: {:.0} img/s -> sparse speedup {:.2}x",
        dense.images_per_sec(&dev),
        design.images_per_sec(&dev) / dense.images_per_sec(&dev)
    );

    // -- 4. the batched search engine ---------------------------------
    // instead of hand-picking 0.6, let TPE search per-layer sparsity
    // against the Eq. 6 objective: 4-candidate generations, evaluated on
    // all cores, with memoized DSE pricings on a 2^-12 sparsity grid
    let ev = SurrogateEvaluator { net: net.clone(), sparsity, base_acc: 69.75 };
    let cfg = SearchConfig {
        iterations: 32,
        engine: EngineConfig::batched(4),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let result = Engine::new(&ev, &net, &rm, &dev).search(&cfg);
    let best = result.best_record();
    println!(
        "searched: best acc {:.2}% | sparsity {:.3} | {:.0} img/s | {:.3e} img/cycle/DSP in {:?}",
        best.accuracy,
        best.avg_sparsity,
        best.images_per_sec,
        best.efficiency,
        t0.elapsed()
    );
    println!(
        "engine: {} generations x {} candidates on {} thread(s), cache hit rate {:.0}%",
        result.stats.generations,
        result.stats.batch,
        result.stats.threads,
        result.stats.cache_hit_rate() * 100.0
    );
}
