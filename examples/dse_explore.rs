//! DSE walk-through on the paper's target geometries (Fig. 4's view).
//!
//! For each network: run the resource-constrained DSE at a realistic
//! sparsity profile, print the per-layer MAC/SPE + #SPE allocation for
//! the 3×3 convolutions (the paper's Fig. 4 plots exactly this for
//! ResNet-18), and validate the analytical throughput with the
//! cycle-level simulator where the geometry is small enough.
//!
//! Run: `cargo run --release --example dse_explore [-- --network resnet18]`

use hass::arch::{networks, Op};
use hass::dse::{explore, DseConfig};
use hass::hardware::device::DeviceBudget;
use hass::hardware::resources::ResourceModel;
use hass::pruning::PruningPlan;
use hass::simulator::{simulate, stages_from_design, SparsityDynamics};
use hass::sparsity::synthesize;
use hass::util::cli::Cli;

fn main() {
    let cli = Cli::new("resource-constrained DSE exploration (Fig. 4)")
        .opt("network", "resnet18", "geometry to explore")
        .opt("w-target", "0.7", "uniform weight-sparsity target")
        .opt("a-target", "0.4", "uniform activation-sparsity target")
        .opt("device", "u250", "device budget");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = cli.parse_from(&args).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });

    let net = networks::by_name(p.get("network")).expect("network");
    let dev = DeviceBudget::by_name(p.get("device")).expect("device");
    let rm = ResourceModel::default();

    // per-layer thresholds from targets through the synthesized curves —
    // per-layer *sparsity statistics* then differ layer to layer, which is
    // what makes Fig. 4's allocation non-uniform
    let sparsity = synthesize(&net, 42);
    let n = sparsity.layers.len();
    let mut x = vec![0.0; 2 * n];
    for i in 0..n {
        x[2 * i] = p.get_f64("w-target") / hass::pruning::MAX_SPARSITY;
        x[2 * i + 1] = p.get_f64("a-target") / hass::pruning::MAX_SPARSITY;
    }
    let plan = PruningPlan::from_unit_point(&x, &sparsity);
    let points = plan.points(&sparsity);

    let t0 = std::time::Instant::now();
    let d = explore(&net, &points, &rm, &dev, &DseConfig::default());
    println!(
        "[dse] {} on {}: {:.0} img/s | {} DSP | {} kLUT | DSE in {:?}\n",
        net.name,
        dev.name,
        d.images_per_sec(&dev),
        d.resources.dsp,
        d.resources.lut / 1000,
        t0.elapsed()
    );

    // Fig. 4: allocation across the 3x3 conv layers
    println!(
        "{:<22} {:>5} {:>9} {:>7} {:>7} {:>9}",
        "3x3 conv layer", "S̄", "MAC/SPE", "i_par", "o_par", "#SPE"
    );
    for ((l, des), pt) in net.compute_layers().iter().zip(&d.designs).zip(&points) {
        if let Op::Conv { kernel: 3, groups: 1, .. } = l.op {
            println!(
                "{:<22} {:>5.2} {:>9} {:>7} {:>7} {:>9}",
                l.name,
                pt.pair_sparsity(),
                des.n_mac,
                des.i_par,
                des.o_par,
                des.engines()
            );
        }
    }

    // simulator validation (small geometries only: the sim is per-group)
    if net.compute_layers().iter().map(|l| l.outputs_per_image()).sum::<usize>() < 3_000_000 {
        let cfgs = stages_from_design(&net, &d.designs, &points, rm.fifo_depth);
        let rep = simulate(&net, &cfgs, 3, SparsityDynamics::Deterministic);
        println!(
            "\n[sim] {:.3e} img/cyc vs model {:.3e} ({:+.2}%)",
            rep.throughput,
            d.throughput,
            (rep.throughput / d.throughput - 1.0) * 100.0
        );
    } else {
        println!("\n[sim] geometry too large for the per-group simulator demo; see `model_vs_sim` bench");
    }
}
