//! Masked fine-tuning after one-shot pruning — the paper's §VII
//! future-work item, implemented end-to-end from Rust.
//!
//! The AOT `train_step.hlo.txt` artifact exports one SGD step with the
//! clip thresholds inside the forward pass, so pruned weights receive
//! zero gradient: running steps at fixed thresholds is masked
//! fine-tuning.  This example prunes CalibNet hard enough to dent its
//! accuracy, then recovers most of the drop in a few dozen steps —
//! without Python anywhere at run time.
//!
//! Run: `make artifacts && cargo run --release --example finetune`

use hass::runtime::train::TrainRuntime;
use hass::runtime::{default_dir, ModelRuntime};
use hass::util::cli::Cli;

fn main() {
    let cli = Cli::new("masked fine-tuning after one-shot pruning")
        .opt("tau", "0.08", "uniform pruning threshold (weights + activations)")
        .opt("steps", "30", "SGD steps")
        .opt("lr", "0.01", "learning rate")
        .opt("batches", "4", "evaluation batches");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = cli.parse_from(&args).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let dir = default_dir();
    let rt = match ModelRuntime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("cannot load artifacts: {e:#}\nrun `make artifacts` first");
            std::process::exit(1);
        }
    };
    let l = rt.n_layers();
    let tau = vec![p.get_f64("tau"); l];
    let zeros = vec![0.0; l];
    let n_eval = p.get_usize("batches");

    let dense = rt.evaluate(&zeros, &zeros, n_eval).expect("eval");
    let pruned = rt.evaluate(&tau, &tau, n_eval).expect("eval");
    println!(
        "[finetune] dense acc {:.2}% | one-shot pruned (tau={}) acc {:.2}%",
        dense.accuracy * 100.0,
        p.get("tau"),
        pruned.accuracy * 100.0
    );
    println!(
        "[finetune] pruned op density {:.3} (mean over layers)",
        pruned.pair_density.iter().sum::<f64>() / l as f64
    );

    // fine-tune with the mask in place
    let mut tr = TrainRuntime::load(&dir).expect("train runtime");
    let steps = p.get_usize("steps");
    let lr = p.get_f64("lr") as f32;
    let t0 = std::time::Instant::now();
    for s in 0..steps {
        let loss = tr.step(s, &tau, &tau, lr).expect("train step");
        if s % 5 == 0 || s + 1 == steps {
            println!("[finetune] step {s:>3}: loss {loss:.4}");
        }
    }
    println!("[finetune] {steps} steps in {:?}", t0.elapsed());

    // evaluate the fine-tuned parameters: write them into a fresh runtime
    // via the weights file round-trip (the runtime keeps weights resident)
    let tuned_dir = std::env::temp_dir().join("hass_finetuned");
    std::fs::create_dir_all(&tuned_dir).ok();
    for f in ["model.hlo.txt", "meta.json", "calib_images.bin", "calib_labels.bin"] {
        std::fs::copy(dir.join(f), tuned_dir.join(f)).expect("copy artifact");
    }
    let mut blob: Vec<u8> = Vec::new();
    for (w, b) in &tr.params {
        for v in w.iter().chain(b) {
            blob.extend_from_slice(&v.to_le_bytes());
        }
    }
    std::fs::write(tuned_dir.join("weights.bin"), blob).expect("write tuned weights");
    let rt2 = ModelRuntime::load(&tuned_dir).expect("reload tuned model");
    let tuned = rt2.evaluate(&tau, &tau, n_eval).expect("eval");
    println!(
        "[finetune] fine-tuned acc {:.2}% (recovered {:+.2} points at the same thresholds)",
        tuned.accuracy * 100.0,
        (tuned.accuracy - pruned.accuracy) * 100.0
    );
    std::fs::remove_dir_all(&tuned_dir).ok();
}
