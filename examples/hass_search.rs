//! End-to-end HASS driver (`hass-e2e` in DESIGN.md §5).
//!
//! Proves the whole three-layer stack composes on a real workload:
//!
//! * the AOT CalibNet artifact (JAX L2 + Pallas L1, compiled at build
//!   time) is loaded by the PJRT runtime — Python is not running;
//! * the TPE search (Eq. 6) proposes per-layer thresholds, *measures*
//!   accuracy and sparsity counters through PJRT, and prices each design
//!   with the DSE on a U250-class budget;
//! * the winning design is cross-checked with the cycle-level simulator.
//!
//! Run: `make artifacts && cargo run --release --example hass_search`
//! Flags: `--iters N --batches K --seed S --journal results/e2e.csv`

use hass::arch::networks;
use hass::coordinator::{
    search, search_sharded, EngineConfig, MeasuredEvaluator, SearchConfig, SearchMode,
};
use hass::hardware::device::DeviceBudget;
use hass::hardware::resources::ResourceModel;
use hass::runtime::ModelRuntime;
use hass::simulator::{simulate, stages_from_design, SparsityDynamics};
use hass::util::cli::Cli;

fn main() {
    let cli = Cli::new("end-to-end HASS search over the AOT CalibNet artifact")
        .opt("iters", "32", "TPE iterations")
        .opt("batches", "4", "calibration batches per evaluation (64 imgs each)")
        .opt("batch", "4", "candidates per TPE generation, evaluated in parallel")
        .opt("threads", "0", "evaluation worker threads (0 = auto)")
        .flag("no-cache", "disable the DSE design cache")
        .opt("seed", "0", "search seed")
        .opt("device", "u250", "device budget")
        .opt(
            "devices",
            "",
            "comma-separated budgets for a sharded multi-device search \
             (e.g. u250,7v690t,stratix10; overrides --device)",
        )
        .opt("journal", "results/e2e_search.csv", "journal CSV path");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = match cli.parse_from(&args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };

    // ---- load the AOT artifact (build-time Python output) -----------
    let rt = match ModelRuntime::load_default() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("cannot load artifacts: {e:#}\nrun `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!(
        "[e2e] artifact {} | dense val acc {:.2}% | {} calib images",
        rt.meta.model,
        rt.meta.dense_val_accuracy * 100.0,
        rt.meta.n_calib
    );

    // ---- search ------------------------------------------------------
    let net = networks::calibnet();
    let rm = ResourceModel::default();
    let cfg = SearchConfig {
        iterations: p.get_usize("iters"),
        seed: p.get_u64("seed"),
        mode: SearchMode::HardwareAware,
        engine: EngineConfig {
            threads: p.get_usize("threads"),
            cache: !p.get_bool("no-cache"),
            ..EngineConfig::batched(p.get_usize("batch"))
        },
        ..Default::default()
    };
    let ev = MeasuredEvaluator::new(rt, p.get_usize("batches"));

    // ---- sharded multi-device sweep (--devices a,b,...) --------------
    let devices = DeviceBudget::parse_list(p.get("devices")).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    if devices.len() >= 2 {
        let t0 = std::time::Instant::now();
        let r = search_sharded(&ev, &net, &rm, &devices, &cfg);
        println!(
            "[e2e] sharded search: {} devices x {} iterations in {:?} | \
             shared cache {} entries, {} hit / {} miss",
            r.stats.devices,
            cfg.iterations,
            t0.elapsed(),
            r.stats.cache_entries,
            r.stats.cache_hits,
            r.stats.cache_misses
        );
        print!("{}", r.summary_table().to_markdown());
        println!("[e2e] cross-device pareto front:");
        print!("{}", r.pareto_table().to_markdown());
        let journal = p.get("journal");
        if !journal.is_empty() {
            match r.write_journals(journal) {
                Ok(paths) => {
                    for path in paths {
                        println!("[e2e] journal -> {path}");
                    }
                }
                Err(e) => {
                    eprintln!("[e2e] failed to write journals: {e}");
                    std::process::exit(1);
                }
            }
        }
        return;
    }
    let dev = devices
        .into_iter()
        .next()
        .unwrap_or_else(|| DeviceBudget::by_name(p.get("device")).expect("device"));
    let t0 = std::time::Instant::now();
    let result = search(&ev, &net, &rm, &dev, &cfg);
    let wall = t0.elapsed();
    let b = result.best_record();
    println!(
        "[e2e] {} iterations in {wall:?} ({:.2} s/iter)",
        cfg.iterations,
        wall.as_secs_f64() / cfg.iterations as f64
    );
    println!(
        "[e2e] engine: {} generations x batch {} on {} thread(s) | cache hit rate {:.0}%",
        result.stats.generations,
        result.stats.batch,
        result.stats.threads,
        result.stats.cache_hit_rate() * 100.0
    );
    println!(
        "[e2e] best @ iter {}: accuracy {:.2}% (dense {:.2}%) | avg sparsity {:.3}",
        b.iter,
        b.accuracy,
        ev.base_accuracy_public(),
        b.avg_sparsity
    );
    println!(
        "[e2e] hardware: {:.0} img/s | {} DSP | {:.3e} img/cycle/DSP (dense ref {:.0} img/s)",
        b.images_per_sec, b.dsp, b.efficiency, result.dense_images_per_sec
    );

    // ---- cross-check the winner against the cycle simulator ----------
    let plan = &b.plan;
    let ev_point = hass::coordinator::Evaluate::eval(&ev, plan);
    let design = hass::dse::explore(&net, &ev_point.points, &rm, &dev, &cfg.dse);
    let cfgs = stages_from_design(&net, &design.designs, &ev_point.points, rm.fifo_depth);
    let det = simulate(&net, &cfgs, 4, SparsityDynamics::Deterministic);
    let sto = simulate(&net, &cfgs, 4, SparsityDynamics::Stochastic { seed: 7 });
    println!(
        "[e2e] simulator check: deterministic {:.3e} img/cyc vs model {:.3e} ({:+.1}%); \
         with run-time sparsity variance {:.3e} ({:+.1}%)",
        det.throughput,
        design.throughput,
        (det.throughput / design.throughput - 1.0) * 100.0,
        sto.throughput,
        (sto.throughput / design.throughput - 1.0) * 100.0,
    );

    // ---- journal ------------------------------------------------------
    let journal = p.get("journal");
    if !journal.is_empty() {
        if let Some(dir) = std::path::Path::new(journal).parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(journal, result.to_table().to_csv()).expect("write journal");
        println!("[e2e] journal -> {journal}");
    }
}

/// Small helper so the example can print the dense baseline accuracy.
trait BaseAcc {
    fn base_accuracy_public(&self) -> f64;
}

impl BaseAcc for MeasuredEvaluator {
    fn base_accuracy_public(&self) -> f64 {
        hass::coordinator::Evaluate::base_accuracy(self)
    }
}
